//! Experience replay: off-policy rollout mixing through the pooled
//! learner pipeline (DESIGN.md §Replay).
//!
//! IMPALA's v-trace correction makes learning from stale trajectories
//! sound — every [`Rollout`] already carries the behaviour-policy
//! logits, so the learner's rho/c clipping handles the off-policyness
//! of a replayed rollout exactly like it handles ordinary actor lag.
//! The [`ReplayBuffer`] exploits that: a bounded, **preallocated**
//! ring of rollout slots the driver's stacker thread feeds — each
//! completed fresh rollout is copied in place into a slot before its
//! pooled buffer recycles into the `RolloutPool`, and once the ring
//! has warmed up (filled to capacity) every learner batch is composed
//! of `(1 − replay_ratio)·B` fresh + `replay_ratio·B` uniformly
//! sampled replayed rollouts ([`stack_mixed`]).
//!
//! Discipline carried over from the rest of the pipeline:
//!
//! * **Zero allocation at steady state** — slots are preallocated at
//!   construction and written with [`Rollout::copy_from`]; sampling
//!   returns a reference straight into the ring, stacked via
//!   [`stack_rollout_into`] with no intermediate copy.  Gated by
//!   `tests/alloc_regression.rs`.
//! * **Reproducibility** — sampling draws from a seeded
//!   [`Rng`](crate::util::rng::Rng) stream, so a fixed seed replays
//!   the same mixture.
//! * **Byte-identical opt-out** — `--replay_capacity 0` (the default)
//!   never constructs a buffer; with a buffer present,
//!   `--replay_ratio 0` plans zero replayed columns, so the stacked
//!   batches are bit-identical to the classic path (pinned by test).
//!
//! Occupancy is reported into [`PipelineGauges`] (`replay_size`,
//! `replay_sampled`, `replay_evicted`) — visible in the driver's
//! periodic report line and the `GaugeSampler` CSV.

use std::fmt;
use std::sync::Arc;

use crate::coordinator::rollout::{stack_rollout_into, Rollout};
use crate::runtime::{LearnerBatch, Manifest};
use crate::telemetry::gauges::PipelineGauges;
use crate::telemetry::trace::{self, Stage};
use crate::util::rng::Rng;

/// Domain-separation constant folded into the run seed for the
/// sampling RNG stream ("replay" in ASCII), so replay draws never
/// alias an actor's action-sampling stream.
const REPLAY_SEED_STREAM: u64 = 0x0000_7265_706C_6179;

/// Bounded preallocated ring of rollout slots with FIFO eviction and
/// seeded uniform sampling.  Owned by one thread (the driver's
/// stacker); telemetry reads go through the shared gauge registry.
pub struct ReplayBuffer {
    /// All slots, preallocated at construction (physical order).
    slots: Vec<Rollout>,
    /// Slots currently holding a rollout (≤ capacity).  Grows until
    /// the ring fills; FIFO eviction holds it at capacity, staleness
    /// eviction can shrink it back down.
    len: usize,
    /// Next physical write position (`== (tail + len) % capacity`).
    head: usize,
    /// Physical position of the **oldest** stored rollout — the FIFO
    /// victim, and where staleness eviction trims from.
    tail: usize,
    /// Staleness bound in policy versions (`--replay_staleness`);
    /// 0 = unbounded, the pre-staleness behavior byte for byte.
    staleness: u64,
    /// Newest published weight version, fed by the stacker each round
    /// via [`set_current_version`](ReplayBuffer::set_current_version).
    current_version: u64,
    /// Warmup latch: set once the ring first fills.  Staleness
    /// eviction may shrink `len` below capacity afterwards, but the
    /// warmup gate never closes again (the early-over-replay hazard it
    /// guards against is gone for good once the ring has been full).
    has_warmed: bool,
    rng: Rng,
    gauges: Arc<PipelineGauges>,
    inserted: u64,
    sampled: u64,
    evicted: u64,
    stale_evicted: u64,
}

impl ReplayBuffer {
    /// Preallocate `capacity` slots of the given rollout shape, with
    /// sampling seeded by `seed` (occupancy reported into a detached
    /// gauge registry; the driver uses
    /// [`with_gauges`](ReplayBuffer::with_gauges) to share one).
    pub fn new(
        capacity: usize,
        t: usize,
        obs_len: usize,
        num_actions: usize,
        seed: u64,
    ) -> ReplayBuffer {
        ReplayBuffer::with_gauges(
            capacity,
            t,
            obs_len,
            num_actions,
            seed,
            PipelineGauges::shared(),
        )
    }

    /// [`new`](ReplayBuffer::new), reporting occupancy (`replay_size`,
    /// `replay_sampled`, `replay_evicted`) into a shared registry.
    pub fn with_gauges(
        capacity: usize,
        t: usize,
        obs_len: usize,
        num_actions: usize,
        seed: u64,
        gauges: Arc<PipelineGauges>,
    ) -> ReplayBuffer {
        assert!(capacity > 0, "replay buffer needs at least one slot");
        let slots = (0..capacity)
            .map(|_| Rollout::new(t, obs_len, num_actions))
            .collect();
        gauges.replay_size.set(0);
        ReplayBuffer {
            slots,
            len: 0,
            head: 0,
            tail: 0,
            staleness: 0,
            current_version: 0,
            has_warmed: false,
            rng: Rng::new(seed ^ REPLAY_SEED_STREAM),
            gauges,
            inserted: 0,
            sampled: 0,
            evicted: 0,
            stale_evicted: 0,
        }
    }

    /// Set the staleness bound K in policy versions (0 = unbounded):
    /// stored rollouts whose stamp lags the current version by more
    /// than K are evicted on insert/sample instead of trained on.
    pub fn set_staleness(&mut self, k: u64) {
        self.staleness = k;
    }

    /// Advance the learner's published weight version (monotone; stale
    /// values are ignored) and prefix-evict ring slots that fell out
    /// of the staleness window, freeing their capacity for fresh
    /// inserts.
    // tb-lint: no-alloc
    pub fn set_current_version(&mut self, v: u64) {
        self.current_version = self.current_version.max(v);
        self.evict_stale();
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Rollouts currently stored.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The warmup gate: sampling only begins once the ring has filled
    /// to capacity, so early batches never over-replay the first few
    /// (highly correlated) rollouts.  A latch: staleness eviction may
    /// later shrink the ring, but the gate never closes again.
    pub fn warmed_up(&self) -> bool {
        self.has_warmed
    }

    /// The rollout at *logical* index `i` (0 = oldest stored), if any.
    /// Exposes the FIFO order for tests and inspection.
    pub fn get(&self, i: usize) -> Option<&Rollout> {
        if i >= self.len {
            return None;
        }
        Some(&self.slots[(self.tail + i) % self.capacity()])
    }

    /// Whether the rollout at logical index `i` has fallen out of the
    /// staleness window.
    // tb-lint: no-alloc
    fn is_stale_at(&self, i: usize) -> bool {
        let phys = (self.tail + i) % self.capacity();
        is_stale(
            self.current_version,
            self.slots[phys].policy_version,
            self.staleness,
        )
    }

    /// Trim stale rollouts off the FIFO front.  Version stamps are
    /// only roughly monotone in insertion order (actors race), so this
    /// clears the prefix cheaply; stragglers further in are skipped by
    /// [`sample`](ReplayBuffer::sample)'s probe instead.
    // tb-lint: no-alloc
    fn evict_stale(&mut self) {
        if self.staleness == 0 || self.len == 0 {
            return;
        }
        let cap = self.capacity();
        let before = self.len;
        while self.len > 0 && self.is_stale_at(0) {
            self.tail = (self.tail + 1) % cap;
            self.len -= 1;
            self.stale_evicted += 1;
            self.gauges.replay_evicted.inc();
        }
        if self.len != before {
            self.gauges.replay_size.set(self.len as u64);
        }
    }

    /// Stored rollouts currently inside the staleness window (== `len`
    /// while the bound is disabled).  `plan` caps at this, so a batch
    /// never asks for more replayed columns than are legal to sample.
    pub fn fresh_len(&self) -> usize {
        if self.staleness == 0 {
            return self.len;
        }
        (0..self.len).filter(|&i| !self.is_stale_at(i)).count()
    }

    /// Copy `r` in place into the next ring slot, evicting the oldest
    /// stored rollout once the ring is full (FIFO) — after trimming
    /// any rollouts the staleness bound has expired.  No allocation.
    // tb-lint: no-alloc
    pub fn insert(&mut self, r: &Rollout) {
        debug_assert!(r.is_complete(), "only complete rollouts are replayable");
        let sp = trace::span(Stage::ReplayInsert);
        self.evict_stale();
        let cap = self.capacity();
        if self.len == cap {
            self.tail = (self.tail + 1) % cap;
            self.len -= 1;
            self.evicted += 1;
            self.gauges.replay_evicted.inc();
        }
        self.slots[self.head].copy_from(r);
        self.head = (self.head + 1) % cap;
        self.len += 1;
        self.inserted += 1;
        if self.len == cap {
            self.has_warmed = true;
        }
        self.gauges.replay_size.set(self.len as u64);
        sp.finish();
    }

    /// Sample one stored rollout uniformly (seeded stream, with
    /// replacement across calls).  Returns a reference straight into
    /// the ring — stack it with [`stack_rollout_into`] and it never
    /// leaves its slot.  `None` while the buffer is empty or every
    /// stored rollout is outside the staleness window.
    ///
    /// With a staleness bound set, a draw landing on a stale
    /// mid-ring slot (version stamps are only roughly monotone in
    /// insertion order) probes forward cyclically to the next fresh
    /// slot — so a returned rollout is **never** older than the bound.
    // tb-lint: no-alloc
    pub fn sample(&mut self) -> Option<&Rollout> {
        let sp = trace::span(Stage::ReplaySample);
        self.evict_stale();
        if self.len == 0 {
            return None; // span drop still records the miss
        }
        let mut i = self.rng.below(self.len);
        let mut probed = 0;
        while self.is_stale_at(i) {
            probed += 1;
            if probed >= self.len {
                return None; // nothing fresh remains
            }
            i = (i + 1) % self.len;
        }
        self.sampled += 1;
        self.gauges.replay_sampled.inc();
        sp.finish();
        self.get(i)
    }

    /// How many of a `batch_size`-rollout learner batch should come
    /// from replay this round: 0 until the warmup gate opens, then
    /// [`replay_count`]`(batch_size, ratio)` — additionally capped at
    /// the sampleable (fresh) count, so a ring smaller than
    /// `round(ratio·B)` — or one partly expired by the staleness
    /// bound — degrades to fewer replayed columns instead of
    /// overdrawing (sampling is with replacement, but `stack_mixed`
    /// refuses to draw more columns than the ring holds).
    pub fn plan(&self, batch_size: usize, ratio: f64) -> usize {
        if !self.warmed_up() {
            return 0;
        }
        replay_count(batch_size, ratio).min(self.fresh_len())
    }

    /// Lifetime counters, for `TrainReport`.
    pub fn stats(&self) -> ReplayStats {
        ReplayStats {
            capacity: self.capacity(),
            len: self.len,
            inserted: self.inserted,
            sampled: self.sampled,
            evicted: self.evicted,
            stale_evicted: self.stale_evicted,
        }
    }
}

/// Whether a rollout stamped `policy_version` is outside the
/// staleness window at `current` under bound `k` (0 = unbounded,
/// nothing is ever stale) — the staleness predicate shared by the
/// ring's eviction and sampling paths.
// tb-lint: no-alloc
pub fn is_stale(current: u64, policy_version: u64, k: u64) -> bool {
    k > 0 && current.saturating_sub(policy_version) > k
}

/// Replayed rollouts per batch of `batch_size` at mixing `ratio`:
/// `round(ratio · B)`, capped at `B − 1` so every batch carries at
/// least one fresh rollout — otherwise the stacker would stop
/// draining the learner queue and the buffer would never refresh.
pub fn replay_count(batch_size: usize, ratio: f64) -> usize {
    debug_assert!(batch_size > 0);
    let k = (ratio.clamp(0.0, 1.0) * batch_size as f64).round() as usize;
    k.min(batch_size - 1)
}

/// Compose one learner batch from `fresh` rollouts (columns
/// `0..fresh.len()`) plus `replayed` uniform samples from the replay
/// ring (columns `fresh.len()..B`), all through the existing
/// time-major [`stack_rollout_into`] path.  `replayed == 0` makes
/// this bit-identical to
/// [`stack_rollouts`](crate::coordinator::rollout::stack_rollouts)
/// (pinned by test).  The caller inserts the fresh rollouts into the
/// ring *afterwards* (so a rollout never competes with itself within
/// its own batch) and then recycles them into the `RolloutPool`.
// tb-lint: no-alloc
pub fn stack_mixed(
    fresh: &[Rollout],
    replay: &mut ReplayBuffer,
    replayed: usize,
    m: &Manifest,
    batch: &mut LearnerBatch,
) {
    let b = m.batch_size;
    assert_eq!(
        fresh.len() + replayed,
        b,
        "mixed batch must fill exactly B columns ({} fresh + {replayed} replayed != {b})",
        fresh.len()
    );
    assert!(
        replayed <= replay.len(),
        "cannot sample {replayed} rollouts from a replay buffer holding {}",
        replay.len()
    );
    for (bi, r) in fresh.iter().enumerate() {
        stack_rollout_into(r, bi, m, batch);
    }
    for bi in fresh.len()..b {
        let r = replay.sample().expect("checked non-empty above"); // tb-lint: allow(unwrap, non-empty verified before the loop)
        stack_rollout_into(r, bi, m, batch);
    }
}

/// Lifetime summary of a run's replay buffer, carried in
/// `TrainReport::replay` when the subsystem is active
/// (`--replay_capacity` > 0 and `--replay_ratio` > 0).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ReplayStats {
    pub capacity: usize,
    /// Slots filled at the end of the run.
    pub len: usize,
    pub inserted: u64,
    pub sampled: u64,
    /// FIFO evictions (ring at capacity).
    pub evicted: u64,
    /// Staleness evictions (`--replay_staleness` expired the slot).
    pub stale_evicted: u64,
}

impl fmt::Display for ReplayStats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "size {}/{} inserted {} sampled {} evicted {}",
            self.len, self.capacity, self.inserted, self.sampled, self.evicted
        )?;
        // quiet while the staleness bound is off (or never fired)
        if self.stale_evicted > 0 {
            write!(f, " stale-evicted {}", self.stale_evicted)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::rollout::stack_rollouts;
    use crate::runtime::manifest::{DType, LeafSpec};
    use std::path::PathBuf;

    const T: usize = 3;
    const OBS: usize = 4;
    const A: usize = 2;

    fn tiny_manifest(b: usize) -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            env: "catch".into(),
            model: "minatar".into(),
            obs_shape: [1, 2, 2],
            num_actions: A,
            unroll_length: T,
            batch_size: b,
            inference_batch: 4,
            inference_sizes: vec![4],
            param_count: 1,
            params: vec![LeafSpec {
                name: "w".into(),
                shape: vec![1],
                dtype: DType::F32,
            }],
            opt_state: vec![],
            stats_names: vec![],
            hyperparams: crate::util::json::Json::Obj(vec![]),
            hlo_sha256: String::new(),
        }
    }

    /// A complete rollout whose every field encodes `tag` — sampling
    /// and stacking tests identify rollouts by it.
    fn tagged(tag: f32) -> Rollout {
        let mut r = Rollout::new(T, OBS, A);
        for i in 0..=T {
            let obs: Vec<f32> = (0..OBS).map(|k| tag + i as f32 + k as f32 * 0.1).collect();
            r.set_obs(i, &obs);
        }
        for i in 0..T {
            let logits: Vec<f32> = (0..A).map(|k| tag + k as f32).collect();
            r.set_transition(i, i % A, &logits, tag, i == T - 1);
        }
        r
    }

    /// The tag a stored rollout was built from (rewards are constant
    /// per rollout above).
    fn tag_of(r: &Rollout) -> f32 {
        r.rewards[0]
    }

    #[test]
    fn fills_then_evicts_fifo() {
        let mut rb = ReplayBuffer::new(3, T, OBS, A, 1);
        assert!(rb.is_empty());
        assert!(!rb.warmed_up());
        for k in 0..3 {
            rb.insert(&tagged(k as f32));
        }
        assert_eq!(rb.len(), 3);
        assert!(rb.warmed_up());
        assert_eq!(rb.stats().evicted, 0);
        // two more inserts evict the two oldest (FIFO): stored = 2,3,4
        rb.insert(&tagged(3.0));
        rb.insert(&tagged(4.0));
        assert_eq!(rb.len(), 3, "ring never grows past capacity");
        let stored: Vec<f32> = (0..3).map(|i| tag_of(rb.get(i).unwrap())).collect();
        assert_eq!(stored, vec![2.0, 3.0, 4.0], "oldest evicted first");
        assert!(rb.get(3).is_none());
        let s = rb.stats();
        assert_eq!((s.inserted, s.evicted, s.len), (5, 2, 3));
    }

    #[test]
    fn gauges_track_size_samples_and_evictions() {
        let g = PipelineGauges::shared();
        let mut rb = ReplayBuffer::with_gauges(2, T, OBS, A, 9, g.clone());
        rb.insert(&tagged(0.0));
        assert_eq!(g.replay_size.get(), 1);
        rb.insert(&tagged(1.0));
        rb.insert(&tagged(2.0));
        assert_eq!(g.replay_size.get(), 2, "size saturates at capacity");
        assert_eq!(g.replay_evicted.get(), 1);
        rb.sample().unwrap();
        rb.sample().unwrap();
        assert_eq!(g.replay_sampled.get(), 2);
        assert!(g
            .snapshot()
            .to_string()
            .contains("replay 2 (sampled 2 evicted 1)"));
    }

    /// Reproducibility contract: the same seed and insert sequence
    /// draw the same sample sequence; a different seed draws a
    /// different one (with overwhelming probability over 64 draws).
    #[test]
    fn sampling_is_deterministic_under_a_fixed_seed() {
        let draw = |seed: u64| -> Vec<f32> {
            let mut rb = ReplayBuffer::new(8, T, OBS, A, seed);
            for k in 0..8 {
                rb.insert(&tagged(k as f32));
            }
            (0..64).map(|_| tag_of(rb.sample().unwrap())).collect()
        };
        let a = draw(7);
        assert_eq!(a, draw(7), "same seed must replay the same mixture");
        assert_ne!(a, draw(8), "different seeds must not alias");
        // uniform-ish: 64 draws over 8 slots should touch most slots
        let distinct = {
            let mut v = a.clone();
            v.sort_by(|x, y| x.partial_cmp(y).unwrap());
            v.dedup();
            v.len()
        };
        assert!(distinct >= 5, "sampling collapsed onto {distinct} slots");
    }

    #[test]
    fn sample_on_empty_is_none() {
        let mut rb = ReplayBuffer::new(4, T, OBS, A, 0);
        assert!(rb.sample().is_none());
        assert_eq!(rb.stats().sampled, 0, "a miss is not a sample");
    }

    /// The warmup gate: no sampled rollouts before `replay_capacity`
    /// inserts have filled the ring, regardless of ratio.
    #[test]
    fn plan_gates_sampling_until_warm() {
        let mut rb = ReplayBuffer::new(4, T, OBS, A, 3);
        assert_eq!(rb.plan(8, 0.5), 0, "empty buffer plans no replay");
        for k in 0..3 {
            rb.insert(&tagged(k as f32));
            assert_eq!(rb.plan(8, 0.5), 0, "warming buffer plans no replay");
        }
        rb.insert(&tagged(3.0));
        assert!(rb.warmed_up());
        assert_eq!(rb.plan(8, 0.5), 4, "warm buffer plans round(ratio*B)");
        assert_eq!(rb.plan(8, 0.0), 0, "ratio 0 never replays, even warm");
        // a ring smaller than round(ratio*B) degrades instead of
        // overdrawing: round(0.99*8) = 7 capped at the 4 stored
        assert_eq!(rb.plan(8, 0.99), 4, "plan never exceeds stored rollouts");
    }

    #[test]
    fn replay_count_rounds_and_keeps_one_fresh_column() {
        assert_eq!(replay_count(8, 0.0), 0);
        assert_eq!(replay_count(8, 0.25), 2);
        assert_eq!(replay_count(8, 0.5), 4);
        assert_eq!(replay_count(4, 0.3), 1);
        // the cap: at least one fresh rollout per batch, always
        assert_eq!(replay_count(8, 0.99), 7);
        assert_eq!(replay_count(1, 0.9), 0);
        assert_eq!(replay_count(2, 0.5), 1);
        // out-of-range ratios clamp instead of misbehaving
        assert_eq!(replay_count(8, 1.5), 7);
        assert_eq!(replay_count(8, -0.5), 0);
    }

    /// The acceptance gate: with `replay_ratio` 0 the mixed path
    /// produces **bit-identical** learner batches to the classic
    /// `stack_rollouts` path — inserts happen, batches don't change.
    #[test]
    fn ratio_zero_is_bit_identical_to_classic_stacking() {
        let b = 3;
        let m = tiny_manifest(b);
        let mut rb = ReplayBuffer::new(2, T, OBS, A, 5);
        for round in 0..4 {
            let fresh: Vec<Rollout> =
                (0..b).map(|k| tagged((round * b + k) as f32)).collect();
            let mut classic = LearnerBatch::zeros(&m);
            stack_rollouts(&fresh, &m, &mut classic);

            let replayed = rb.plan(b, 0.0);
            assert_eq!(replayed, 0);
            let mut mixed = LearnerBatch::zeros(&m);
            stack_mixed(&fresh, &mut rb, replayed, &m, &mut mixed);
            for r in &fresh {
                rb.insert(r); // feeding the ring must not touch batches
            }

            assert_eq!(classic.observations, mixed.observations, "round {round}");
            assert_eq!(classic.actions, mixed.actions, "round {round}");
            assert_eq!(classic.rewards, mixed.rewards, "round {round}");
            assert_eq!(classic.dones, mixed.dones, "round {round}");
            assert_eq!(
                classic.behavior_logits, mixed.behavior_logits,
                "round {round}"
            );
        }
        assert_eq!(rb.stats().sampled, 0, "ratio 0 never samples");
        assert!(rb.warmed_up(), "the ring still filled alongside");
    }

    /// Mixing places fresh rollouts in the leading columns and stored
    /// ones in the trailing columns, bit-exact from their ring slots.
    #[test]
    fn mixed_batches_compose_fresh_then_replayed_columns() {
        let b = 4;
        let m = tiny_manifest(b);
        let mut rb = ReplayBuffer::new(2, T, OBS, A, 11);
        rb.insert(&tagged(100.0));
        rb.insert(&tagged(200.0));
        let fresh: Vec<Rollout> = vec![tagged(0.0), tagged(1.0)];
        let replayed = rb.plan(b, 0.5);
        assert_eq!(replayed, 2);
        let mut batch = LearnerBatch::zeros(&m);
        stack_mixed(&fresh, &mut rb, replayed, &m, &mut batch);
        // column bi's reward at t=0 lives at index 0 * b + bi
        let col_tag = |bi: usize| batch.rewards[bi];
        assert_eq!(col_tag(0), 0.0);
        assert_eq!(col_tag(1), 1.0);
        for bi in 2..b {
            let tag = col_tag(bi);
            assert!(
                tag == 100.0 || tag == 200.0,
                "column {bi} must hold a stored rollout, got {tag}"
            );
        }
        assert_eq!(rb.stats().sampled, 2);
    }

    /// A tagged rollout stamped with a behaviour-policy version.
    fn tagged_v(tag: f32, version: u64) -> Rollout {
        let mut r = tagged(tag);
        r.policy_version = version;
        r
    }

    /// Staleness eviction trims the FIFO front oldest-first as the
    /// current version advances, and the warmup latch stays open while
    /// the ring shrinks.
    #[test]
    fn staleness_evicts_oldest_versions_first() {
        let mut rb = ReplayBuffer::new(4, T, OBS, A, 1);
        rb.set_staleness(2);
        for k in 0..4u64 {
            rb.insert(&tagged_v(k as f32, k + 1)); // versions 1..=4
        }
        assert!(rb.warmed_up());
        assert_eq!(rb.stats().stale_evicted, 0);
        rb.set_current_version(4);
        // lag of version v is 4 - v: only version 1 (lag 3) is > 2
        assert_eq!(rb.len(), 3);
        assert_eq!(rb.stats().stale_evicted, 1);
        let stored: Vec<f32> = (0..rb.len()).map(|i| tag_of(rb.get(i).unwrap())).collect();
        assert_eq!(stored, vec![1.0, 2.0, 3.0], "oldest version evicted first");
        rb.set_current_version(6);
        // versions 2 and 3 (lags 4, 3) expire; version 4 (lag 2) stays
        assert_eq!(rb.len(), 1);
        assert_eq!(rb.stats().stale_evicted, 3);
        assert_eq!(tag_of(rb.get(0).unwrap()), 3.0);
        assert!(rb.warmed_up(), "warmup latch survives the eviction shrink");
        assert!(rb.plan(4, 0.5) <= 1, "plan shrinks with the fresh count");
        // freed capacity is reusable: fresh inserts refill without FIFO
        // eviction of live slots
        let evicted_before = rb.stats().evicted;
        rb.insert(&tagged_v(9.0, 6));
        assert_eq!(rb.len(), 2);
        assert_eq!(rb.stats().evicted, evicted_before, "no FIFO victim needed");
        let s = rb.stats();
        assert!(s.to_string().contains("stale-evicted 3"), "{s}");
    }

    /// The acceptance gate: with `--replay_staleness K` set, `sample`
    /// provably never returns a rollout more than K versions old —
    /// even when insertion order carries version inversions (actor
    /// raciness), which prefix eviction alone cannot clear.
    #[test]
    fn sampling_never_returns_rollouts_older_than_k() {
        let mut rb = ReplayBuffer::new(8, T, OBS, A, 3);
        rb.set_staleness(3);
        // insertion order deliberately non-monotone in version
        let versions = [5u64, 2, 7, 3, 8, 2, 9, 10];
        for &v in &versions {
            rb.insert(&tagged_v(v as f32, v));
        }
        assert!(rb.warmed_up());
        rb.set_current_version(10);
        // prefix eviction clears versions 5 and 2 off the front; the
        // mid-ring stale stragglers (3 and 2) stay stored but must
        // never be sampled
        assert_eq!(rb.len(), 6);
        assert_eq!(rb.fresh_len(), 4);
        let mut draws = 0;
        for _ in 0..256 {
            if let Some(v) = rb.sample().map(|r| r.policy_version) {
                draws += 1;
                assert!(10 - v <= 3, "sampled a stale rollout (version {v})");
            }
        }
        assert_eq!(draws, 256, "fresh slots must stay sampleable");
        assert!(rb.plan(8, 0.99) <= rb.fresh_len(), "plan respects freshness");
    }

    /// When every stored rollout has expired, `sample` returns `None`
    /// instead of violating the bound, and `plan` asks for nothing.
    #[test]
    fn fully_stale_ring_samples_nothing() {
        let mut rb = ReplayBuffer::new(2, T, OBS, A, 5);
        rb.set_staleness(1);
        rb.insert(&tagged_v(0.0, 1));
        rb.insert(&tagged_v(1.0, 1));
        assert!(rb.warmed_up());
        rb.set_current_version(100);
        assert_eq!(rb.len(), 0, "everything expired");
        assert!(rb.sample().is_none());
        assert_eq!(rb.plan(4, 0.5), 0);
        assert_eq!(rb.stats().sampled, 0, "a miss is not a sample");
    }

    /// Staleness off (the default) is byte-identical to the old ring:
    /// version stamps are carried but never evict or bias sampling.
    #[test]
    fn staleness_disabled_ignores_version_stamps() {
        let draw = |stale: bool| -> Vec<f32> {
            let mut rb = ReplayBuffer::new(4, T, OBS, A, 21);
            if stale {
                rb.set_current_version(1_000_000);
            }
            for k in 0..4u64 {
                rb.insert(&tagged_v(k as f32, k + 1));
            }
            (0..32).map(|_| tag_of(rb.sample().unwrap())).collect()
        };
        assert_eq!(draw(false), draw(true), "k = 0 must never evict or probe");
    }

    #[test]
    #[should_panic(expected = "exactly B columns")]
    fn mixed_column_mismatch_panics() {
        let m = tiny_manifest(4);
        let mut rb = ReplayBuffer::new(2, T, OBS, A, 0);
        let fresh = vec![tagged(0.0)];
        let mut batch = LearnerBatch::zeros(&m);
        stack_mixed(&fresh, &mut rb, 1, &m, &mut batch); // 1 + 1 != 4
    }

    #[test]
    #[should_panic(expected = "cannot sample")]
    fn mixed_overdraw_on_cold_buffer_panics() {
        let m = tiny_manifest(2);
        let mut rb = ReplayBuffer::new(2, T, OBS, A, 0);
        let fresh = vec![tagged(0.0)];
        let mut batch = LearnerBatch::zeros(&m);
        stack_mixed(&fresh, &mut rb, 1, &m, &mut batch); // nothing stored yet
    }
}
