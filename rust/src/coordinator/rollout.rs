//! Rollout buffers: the paper's learner-input dict
//! (`observation/reward/done/policy_logits/action`, Section 2) as flat
//! reusable buffers.
//!
//! Each actor fills a [`Rollout`] of `unroll_length` transitions
//! (plus the T+1-th bootstrap observation); the learner stacks
//! `batch_size` of them into the time-major [`LearnerBatch`] layout
//! the learner artifact was compiled for (`[T, B, ...]`, index
//! `t * B + b`).

use std::sync::{Arc, Condvar, Mutex};

use crate::runtime::{LearnerBatch, Manifest};
use crate::telemetry::gauges::PipelineGauges;

/// One actor's T-step rollout (batch dimension absent).
#[derive(Debug, Clone)]
pub struct Rollout {
    pub t: usize,
    pub obs_len: usize,
    pub num_actions: usize,
    /// `[T+1, obs_len]`
    pub observations: Vec<f32>,
    /// `[T]`
    pub actions: Vec<i32>,
    /// `[T]`
    pub rewards: Vec<f32>,
    /// `[T]` 1.0 where the episode ended
    pub dones: Vec<f32>,
    /// `[T, A]` behaviour-policy logits
    pub behavior_logits: Vec<f32>,
    /// How many transitions are filled (== t when complete).
    pub filled: usize,
    /// Published weight version the behaviour policy ran at when the
    /// unroll started (0 = unstamped).  The learner's policy lag for
    /// this rollout is `learner_version - policy_version` — the exact
    /// off-policyness v-trace corrects (DESIGN.md §Sharded-Learner).
    pub policy_version: u64,
}

impl Rollout {
    pub fn new(t: usize, obs_len: usize, num_actions: usize) -> Rollout {
        Rollout {
            t,
            obs_len,
            num_actions,
            observations: vec![0.0; (t + 1) * obs_len],
            actions: vec![0; t],
            rewards: vec![0.0; t],
            dones: vec![0.0; t],
            behavior_logits: vec![0.0; t * num_actions],
            filled: 0,
            policy_version: 0,
        }
    }

    /// Write the observation for step `i` (0..=T).
    pub fn set_obs(&mut self, i: usize, obs: &[f32]) {
        debug_assert!(i <= self.t);
        debug_assert_eq!(obs.len(), self.obs_len);
        self.observations[i * self.obs_len..(i + 1) * self.obs_len].copy_from_slice(obs);
    }

    /// Record transition `i`: the action taken from obs_i, its logits,
    /// and the resulting reward/done.
    pub fn set_transition(
        &mut self,
        i: usize,
        action: usize,
        logits: &[f32],
        reward: f32,
        done: bool,
    ) {
        debug_assert!(i < self.t);
        debug_assert_eq!(logits.len(), self.num_actions);
        self.actions[i] = action as i32;
        self.rewards[i] = reward;
        self.dones[i] = if done { 1.0 } else { 0.0 };
        self.behavior_logits[i * self.num_actions..(i + 1) * self.num_actions]
            .copy_from_slice(logits);
        self.filled = self.filled.max(i + 1);
    }

    pub fn is_complete(&self) -> bool {
        self.filled == self.t
    }

    /// Copy another rollout's contents into this buffer **in place**
    /// (both buffers keep their preallocated storage; zero heap
    /// allocation).  This is the replay ring's write primitive —
    /// the same copy-in-place discipline as the pool's recycle path.
    /// Panics on shape mismatch (the slices disagree in length).
    // tb-lint: no-alloc
    pub fn copy_from(&mut self, src: &Rollout) {
        debug_assert_eq!(
            (self.t, self.obs_len, self.num_actions),
            (src.t, src.obs_len, src.num_actions),
            "rollout shape mismatch"
        );
        self.observations.copy_from_slice(&src.observations);
        self.actions.copy_from_slice(&src.actions);
        self.rewards.copy_from_slice(&src.rewards);
        self.dones.copy_from_slice(&src.dones);
        self.behavior_logits.copy_from_slice(&src.behavior_logits);
        self.filled = src.filled;
        self.policy_version = src.policy_version;
    }
}

// ---------------------------------------------------------------------------

/// Bounded recycling pool of [`Rollout`] buffers — the actor→learner
/// half of the paper's §5.1 buffer-reuse discipline (TorchBeast's C++
/// buffer-pool rendezvous).
///
/// Lifecycle: an actor [`rent`](RolloutPool::rent)s an empty buffer,
/// fills it over `unroll_length` steps, and ships the buffer itself
/// through the learner queue (no clone).  After stacking, the learner
/// side [`recycle`](RolloutPool::recycle)s it.  Every buffer is
/// preallocated up front; steady state moves buffers around without a
/// single heap allocation.
///
/// `rent` blocks while the pool is empty (backpressure in addition to
/// the learner queue's); [`close`](RolloutPool::close) unblocks every
/// waiter with `None` so shutdown never deadlocks on a drained pool.
pub struct RolloutPool {
    shared: Arc<PoolShared>,
}

impl Clone for RolloutPool {
    fn clone(&self) -> Self {
        RolloutPool {
            shared: self.shared.clone(),
        }
    }
}

struct PoolShared {
    inner: Mutex<PoolInner>,
    available: Condvar,
    capacity: usize,
    /// Occupancy gauges (relaxed atomics; see telemetry::gauges) —
    /// the driver's shared registry, or a detached default.
    gauges: Arc<PipelineGauges>,
}

struct PoolInner {
    free: Vec<Rollout>,
    closed: bool,
}

impl RolloutPool {
    /// Preallocate `capacity` rollout buffers of the given shape
    /// (occupancy reported into a detached gauge set; the driver uses
    /// [`with_gauges`](RolloutPool::with_gauges) to share one).
    pub fn new(capacity: usize, t: usize, obs_len: usize, num_actions: usize) -> RolloutPool {
        RolloutPool::with_gauges(capacity, t, obs_len, num_actions, PipelineGauges::shared())
    }

    /// [`new`](RolloutPool::new), reporting occupancy (`pool_free`,
    /// `pool_capacity`, `pool_rent_waits`; snapshots derive rented =
    /// capacity − free) into a shared gauge registry.
    pub fn with_gauges(
        capacity: usize,
        t: usize,
        obs_len: usize,
        num_actions: usize,
        gauges: Arc<PipelineGauges>,
    ) -> RolloutPool {
        assert!(capacity > 0, "pool needs at least one buffer");
        let free = (0..capacity)
            .map(|_| Rollout::new(t, obs_len, num_actions))
            .collect();
        // capacity is static; snapshots derive rented = capacity - free
        // from a single dynamic atomic (tear-free pool accounting)
        gauges.pool_capacity.set(capacity as u64);
        gauges.pool_free.set(capacity as u64);
        RolloutPool {
            shared: Arc::new(PoolShared {
                inner: Mutex::new(PoolInner {
                    free,
                    closed: false,
                }),
                available: Condvar::new(),
                capacity,
                gauges,
            }),
        }
    }

    /// The gauge registry this pool reports occupancy into.
    pub fn gauges(&self) -> &Arc<PipelineGauges> {
        &self.shared.gauges
    }

    /// Take a buffer out of the pool, blocking while it is empty.
    /// Returns `None` once the pool has been closed.
    // tb-lint: no-alloc
    pub fn rent(&self) -> Option<Rollout> {
        let g = &self.shared.gauges;
        let mut inner = self.shared.inner.lock().unwrap(); // tb-lint: allow(unwrap, leaf pool lock; poison propagates)
        let mut starved = false;
        loop {
            if inner.closed {
                return None;
            }
            if let Some(r) = inner.free.pop() {
                g.pool_free.sub(1);
                return Some(r);
            }
            if !starved {
                // counted once per blocking rent: how often actors
                // starve on the pool, not how often they re-wake
                starved = true;
                g.pool_rent_waits.inc();
            }
            inner = self.shared.available.wait(inner).unwrap(); // tb-lint: allow(unwrap, leaf pool lock; poison propagates)
        }
    }

    /// Non-blocking rent.
    // tb-lint: no-alloc
    pub fn try_rent(&self) -> Option<Rollout> {
        let mut inner = self.shared.inner.lock().unwrap(); // tb-lint: allow(unwrap, leaf pool lock; poison propagates)
        if inner.closed {
            return None;
        }
        let r = inner.free.pop();
        if r.is_some() {
            self.shared.gauges.pool_free.sub(1);
        }
        r
    }

    /// Return a buffer to the pool (reset for reuse).  Buffers handed
    /// back after close — or beyond capacity — are simply dropped (and
    /// stay counted as rented: they really are gone from the pool).
    // tb-lint: no-alloc
    pub fn recycle(&self, mut r: Rollout) {
        r.filled = 0;
        r.policy_version = 0;
        let mut inner = self.shared.inner.lock().unwrap(); // tb-lint: allow(unwrap, leaf pool lock; poison propagates)
        if inner.closed || inner.free.len() >= self.shared.capacity {
            return;
        }
        inner.free.push(r);
        self.shared.gauges.pool_free.add(1);
        drop(inner);
        self.shared.available.notify_one();
    }

    /// Close the pool: every blocked and future `rent` returns `None`.
    pub fn close(&self) {
        let mut inner = self.shared.inner.lock().unwrap(); // tb-lint: allow(unwrap, leaf pool lock; poison propagates)
        inner.closed = true;
        drop(inner);
        self.shared.available.notify_all();
    }

    /// Buffers currently available for rent.
    pub fn available(&self) -> usize {
        self.shared.inner.lock().unwrap().free.len() // tb-lint: allow(unwrap, leaf pool lock; poison propagates)
    }

    pub fn capacity(&self) -> usize {
        self.shared.capacity
    }
}

// ---------------------------------------------------------------------------

/// Stack B rollouts into the learner's time-major batch.
/// `batch` buffers are reused across calls (no allocation).
// tb-lint: no-alloc
pub fn stack_rollouts(rollouts: &[Rollout], m: &Manifest, batch: &mut LearnerBatch) {
    assert_eq!(rollouts.len(), m.batch_size, "need exactly B rollouts");
    for (bi, r) in rollouts.iter().enumerate() {
        stack_rollout_into(r, bi, m, batch);
    }
}

/// Stack one rollout into batch column `bi` of the time-major layout —
/// the per-column core of [`stack_rollouts`].  Replay mixing
/// ([`crate::coordinator::replay`]) uses it to place sampled rollouts
/// directly from their ring slots, with no intermediate copy and no
/// allocation.
// tb-lint: no-alloc
pub fn stack_rollout_into(r: &Rollout, bi: usize, m: &Manifest, batch: &mut LearnerBatch) {
    let (t, b, a) = (m.unroll_length, m.batch_size, m.num_actions);
    let obs_len = m.obs_len();
    assert!(bi < b, "batch column {bi} out of range (B = {b})");
    assert!(r.is_complete(), "incomplete rollout");
    assert_eq!(r.t, t);
    assert_eq!(r.obs_len, obs_len);
    batch.policy_versions[bi] = r.policy_version;
    for ti in 0..=t {
        let dst = (ti * b + bi) * obs_len;
        let src = ti * obs_len;
        batch.observations[dst..dst + obs_len]
            .copy_from_slice(&r.observations[src..src + obs_len]);
    }
    for ti in 0..t {
        let idx = ti * b + bi;
        batch.actions[idx] = r.actions[ti];
        batch.rewards[idx] = r.rewards[ti];
        batch.dones[idx] = r.dones[ti];
        let dst = idx * a;
        batch.behavior_logits[dst..dst + a]
            .copy_from_slice(&r.behavior_logits[ti * a..(ti + 1) * a]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{DType, LeafSpec};
    use std::path::PathBuf;

    fn tiny_manifest(t: usize, b: usize) -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            env: "catch".into(),
            model: "minatar".into(),
            obs_shape: [1, 2, 2],
            num_actions: 3,
            unroll_length: t,
            batch_size: b,
            inference_batch: 4,
            inference_sizes: vec![4],
            param_count: 1,
            params: vec![LeafSpec {
                name: "w".into(),
                shape: vec![1],
                dtype: DType::F32,
            }],
            opt_state: vec![],
            stats_names: vec![],
            hyperparams: crate::util::json::Json::Obj(vec![]),
            hlo_sha256: String::new(),
        }
    }

    fn fill_rollout(r: &mut Rollout, tag: f32) {
        for i in 0..=r.t {
            let obs: Vec<f32> = (0..r.obs_len).map(|k| tag + i as f32 + k as f32 * 0.1).collect();
            r.set_obs(i, &obs);
        }
        for i in 0..r.t {
            let logits: Vec<f32> = (0..r.num_actions).map(|k| tag + k as f32).collect();
            r.set_transition(i, i % r.num_actions, &logits, tag + i as f32, i == r.t - 1);
        }
    }

    #[test]
    fn rollout_fill_and_complete() {
        let mut r = Rollout::new(4, 4, 3);
        assert!(!r.is_complete());
        fill_rollout(&mut r, 10.0);
        assert!(r.is_complete());
        assert_eq!(r.actions, vec![0, 1, 2, 0]);
        assert_eq!(r.dones, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn stacking_layout_time_major() {
        let m = tiny_manifest(2, 3);
        let mut rollouts = Vec::new();
        for bi in 0..3 {
            let mut r = Rollout::new(2, 4, 3);
            fill_rollout(&mut r, 100.0 * bi as f32);
            rollouts.push(r);
        }
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&rollouts, &m, &mut batch);
        let (t, b, a, obs_len) = (2, 3, 3, 4);
        for bi in 0..b {
            let tag = 100.0 * bi as f32;
            for ti in 0..t {
                let idx = ti * b + bi;
                assert_eq!(batch.rewards[idx], tag + ti as f32, "reward [{ti},{bi}]");
                assert_eq!(batch.actions[idx], (ti % a) as i32);
                // obs row
                let dst = (ti * b + bi) * obs_len;
                assert_eq!(batch.observations[dst], tag + ti as f32);
                // logits row
                let l = idx * a;
                assert_eq!(batch.behavior_logits[l], tag);
                assert_eq!(batch.behavior_logits[l + 2], tag + 2.0);
            }
            // bootstrap obs at t = T
            let dst = (t * b + bi) * obs_len;
            assert_eq!(batch.observations[dst], tag + t as f32);
        }
    }

    /// `copy_from` replicates every field in place — the replay ring's
    /// write primitive must preserve the backing allocation.
    #[test]
    fn copy_from_replicates_in_place() {
        let mut src = Rollout::new(3, 4, 2);
        fill_rollout(&mut src, 7.0);
        let mut dst = Rollout::new(3, 4, 2);
        let ptr = dst.observations.as_ptr();
        dst.copy_from(&src);
        assert_eq!(dst.observations, src.observations);
        assert_eq!(dst.actions, src.actions);
        assert_eq!(dst.rewards, src.rewards);
        assert_eq!(dst.dones, src.dones);
        assert_eq!(dst.behavior_logits, src.behavior_logits);
        assert_eq!(dst.filled, src.filled);
        assert!(dst.is_complete());
        assert_eq!(ptr, dst.observations.as_ptr(), "copy must reuse the buffer");
    }

    /// The version stamp rides every hop: `copy_from` (the replay
    /// ring's write), `stack_rollout_into` (the batch column), and the
    /// pool's recycle reset.
    #[test]
    fn policy_version_stamps_through_copy_stack_and_recycle() {
        let m = tiny_manifest(2, 3);
        let mut rollouts = Vec::new();
        for bi in 0..3 {
            let mut r = Rollout::new(2, 4, 3);
            fill_rollout(&mut r, bi as f32);
            r.policy_version = 100 + bi as u64;
            rollouts.push(r);
        }
        // copy_from carries the stamp
        let mut slot = Rollout::new(2, 4, 3);
        slot.copy_from(&rollouts[1]);
        assert_eq!(slot.policy_version, 101);
        // stacking records one stamp per batch column
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&rollouts, &m, &mut batch);
        assert_eq!(batch.policy_versions, vec![100, 101, 102]);
        // recycle resets the stamp along with filled
        let pool = RolloutPool::new(1, 2, 4, 3);
        let mut r = pool.rent().unwrap();
        r.policy_version = 7;
        pool.recycle(r);
        assert_eq!(pool.rent().unwrap().policy_version, 0);
    }

    /// `stack_rollout_into` must place exactly one batch column — and
    /// agree with the whole-batch `stack_rollouts` path bit for bit.
    #[test]
    fn stack_single_column_matches_whole_batch_path() {
        let m = tiny_manifest(2, 3);
        let mut rollouts = Vec::new();
        for bi in 0..3 {
            let mut r = Rollout::new(2, 4, 3);
            fill_rollout(&mut r, 10.0 * bi as f32);
            rollouts.push(r);
        }
        let mut whole = LearnerBatch::zeros(&m);
        stack_rollouts(&rollouts, &m, &mut whole);
        let mut columns = LearnerBatch::zeros(&m);
        for (bi, r) in rollouts.iter().enumerate() {
            stack_rollout_into(r, bi, &m, &mut columns);
        }
        assert_eq!(whole.observations, columns.observations);
        assert_eq!(whole.actions, columns.actions);
        assert_eq!(whole.rewards, columns.rewards);
        assert_eq!(whole.dones, columns.dones);
        assert_eq!(whole.behavior_logits, columns.behavior_logits);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn stack_column_out_of_range_panics() {
        let m = tiny_manifest(2, 1);
        let mut r = Rollout::new(2, 4, 3);
        fill_rollout(&mut r, 0.0);
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollout_into(&r, 1, &m, &mut batch);
    }

    #[test]
    fn pool_rent_recycle_roundtrip() {
        let pool = RolloutPool::new(2, 3, 4, 2);
        assert_eq!(pool.available(), 2);
        let mut r = pool.rent().unwrap();
        assert_eq!((r.t, r.obs_len, r.num_actions), (3, 4, 2));
        fill_rollout(&mut r, 1.0);
        assert!(r.is_complete());
        pool.recycle(r);
        assert_eq!(pool.available(), 2);
        // recycled buffers come back reset
        let r2 = pool.rent().unwrap();
        assert_eq!(r2.filled, 0);
    }

    #[test]
    fn pool_exhaustion_blocks_until_recycle() {
        let pool = RolloutPool::new(1, 2, 2, 2);
        let held = pool.rent().unwrap();
        assert!(pool.try_rent().is_none());
        let waiter = {
            let pool = pool.clone();
            std::thread::spawn(move || {
                let t0 = std::time::Instant::now();
                let r = pool.rent();
                (r.is_some(), t0.elapsed())
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        pool.recycle(held);
        let (got, blocked_for) = waiter.join().unwrap();
        assert!(got, "rent must succeed after recycle");
        assert!(
            blocked_for >= std::time::Duration::from_millis(10),
            "renter should have blocked, blocked {blocked_for:?}"
        );
    }

    #[test]
    fn pool_close_unblocks_drained_renters() {
        // the shutdown hazard: every buffer is out, actors block on
        // rent, the driver closes — nobody may deadlock.
        let pool = RolloutPool::new(1, 2, 2, 2);
        let held = pool.rent().unwrap();
        let waiters: Vec<_> = (0..3)
            .map(|_| {
                let pool = pool.clone();
                std::thread::spawn(move || pool.rent().is_none())
            })
            .collect();
        std::thread::sleep(std::time::Duration::from_millis(10));
        pool.close();
        for w in waiters {
            assert!(w.join().unwrap(), "rent after close must be None");
        }
        // recycling after close is a harmless drop
        pool.recycle(held);
        assert!(pool.rent().is_none());
    }

    #[test]
    fn pool_never_grows_past_capacity() {
        let pool = RolloutPool::new(1, 2, 2, 2);
        // a foreign buffer recycled into a full pool is dropped
        pool.recycle(Rollout::new(2, 2, 2));
        assert_eq!(pool.available(), pool.capacity());
        // the dropped foreign buffer never entered the accounting:
        // free stays at capacity, so derived rented stays zero
        let s = pool.gauges().snapshot();
        assert_eq!((s.pool_free, s.pool_rented), (1, 0));
    }

    /// Telemetry contract: the pool's gauges track occupancy exactly,
    /// including the starvation counter under pool exhaustion.
    #[test]
    fn gauges_track_occupancy_under_exhaustion() {
        let g = PipelineGauges::shared();
        // snapshot occupancy, cross-checked against the pool's locked
        // ground truth — an unbalanced gauge update cannot hide behind
        // the derived free + rented == capacity identity
        let occ = |pool: &RolloutPool| {
            let s = pool.gauges().snapshot();
            assert_eq!(
                s.pool_free,
                pool.available() as u64,
                "free gauge must match the pool's actual free count"
            );
            (s.pool_free, s.pool_rented)
        };
        let pool = RolloutPool::with_gauges(2, 2, 2, 2, g.clone());
        assert_eq!(occ(&pool), (2, 0));

        let a = pool.rent().unwrap();
        let b = pool.rent().unwrap();
        assert_eq!(occ(&pool), (0, 2));
        assert_eq!(g.pool_rent_waits.get(), 0, "no starvation yet");

        // a renter on the drained pool blocks and is counted starved
        let waiter = {
            let pool = pool.clone();
            std::thread::spawn(move || pool.rent())
        };
        for _ in 0..2000 {
            if g.pool_rent_waits.get() > 0 {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        assert_eq!(g.pool_rent_waits.get(), 1, "blocked rent must count as starved");

        pool.recycle(a);
        let r = waiter.join().unwrap().unwrap();
        // b and r are out; the recycled-then-rented buffer nets out
        assert_eq!(occ(&pool), (0, 2));

        pool.recycle(b);
        pool.recycle(r);
        assert_eq!(occ(&pool), (2, 0));
        assert_eq!(g.pool_rent_waits.get(), 1, "starvation counter is monotonic");
    }

    #[test]
    #[should_panic(expected = "need exactly B rollouts")]
    fn stack_wrong_count_panics() {
        let m = tiny_manifest(2, 3);
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&[], &m, &mut batch);
    }

    #[test]
    #[should_panic(expected = "incomplete rollout")]
    fn stack_incomplete_panics() {
        let m = tiny_manifest(2, 1);
        let r = Rollout::new(2, 4, 3);
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&[r], &m, &mut batch);
    }
}
