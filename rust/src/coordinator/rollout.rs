//! Rollout buffers: the paper's learner-input dict
//! (`observation/reward/done/policy_logits/action`, Section 2) as flat
//! reusable buffers.
//!
//! Each actor fills a [`Rollout`] of `unroll_length` transitions
//! (plus the T+1-th bootstrap observation); the learner stacks
//! `batch_size` of them into the time-major [`LearnerBatch`] layout
//! the learner artifact was compiled for (`[T, B, ...]`, index
//! `t * B + b`).

use crate::runtime::{LearnerBatch, Manifest};

/// One actor's T-step rollout (batch dimension absent).
#[derive(Debug, Clone)]
pub struct Rollout {
    pub t: usize,
    pub obs_len: usize,
    pub num_actions: usize,
    /// `[T+1, obs_len]`
    pub observations: Vec<f32>,
    /// `[T]`
    pub actions: Vec<i32>,
    /// `[T]`
    pub rewards: Vec<f32>,
    /// `[T]` 1.0 where the episode ended
    pub dones: Vec<f32>,
    /// `[T, A]` behaviour-policy logits
    pub behavior_logits: Vec<f32>,
    /// How many transitions are filled (== t when complete).
    pub filled: usize,
}

impl Rollout {
    pub fn new(t: usize, obs_len: usize, num_actions: usize) -> Rollout {
        Rollout {
            t,
            obs_len,
            num_actions,
            observations: vec![0.0; (t + 1) * obs_len],
            actions: vec![0; t],
            rewards: vec![0.0; t],
            dones: vec![0.0; t],
            behavior_logits: vec![0.0; t * num_actions],
            filled: 0,
        }
    }

    /// Write the observation for step `i` (0..=T).
    pub fn set_obs(&mut self, i: usize, obs: &[f32]) {
        debug_assert!(i <= self.t);
        debug_assert_eq!(obs.len(), self.obs_len);
        self.observations[i * self.obs_len..(i + 1) * self.obs_len].copy_from_slice(obs);
    }

    /// Record transition `i`: the action taken from obs_i, its logits,
    /// and the resulting reward/done.
    pub fn set_transition(
        &mut self,
        i: usize,
        action: usize,
        logits: &[f32],
        reward: f32,
        done: bool,
    ) {
        debug_assert!(i < self.t);
        debug_assert_eq!(logits.len(), self.num_actions);
        self.actions[i] = action as i32;
        self.rewards[i] = reward;
        self.dones[i] = if done { 1.0 } else { 0.0 };
        self.behavior_logits[i * self.num_actions..(i + 1) * self.num_actions]
            .copy_from_slice(logits);
        self.filled = self.filled.max(i + 1);
    }

    pub fn is_complete(&self) -> bool {
        self.filled == self.t
    }

    /// Reset for reuse (buffer-recycling discipline of §5.1). The
    /// T+1-th observation of the previous rollout becomes observation
    /// 0 of the next (contiguous experience, like TorchBeast).
    pub fn roll_over(&mut self) {
        let last = self.t * self.obs_len;
        let (head, tail) = self.observations.split_at_mut(last);
        head[..self.obs_len].copy_from_slice(&tail[..self.obs_len]);
        self.filled = 0;
    }
}

/// Stack B rollouts into the learner's time-major batch.
/// `batch` buffers are reused across calls (no allocation).
pub fn stack_rollouts(rollouts: &[Rollout], m: &Manifest, batch: &mut LearnerBatch) {
    let (t, b, a) = (m.unroll_length, m.batch_size, m.num_actions);
    let obs_len = m.obs_len();
    assert_eq!(rollouts.len(), b, "need exactly B rollouts");
    for r in rollouts {
        assert!(r.is_complete(), "incomplete rollout");
        assert_eq!(r.t, t);
        assert_eq!(r.obs_len, obs_len);
    }
    for (bi, r) in rollouts.iter().enumerate() {
        for ti in 0..=t {
            let dst = (ti * b + bi) * obs_len;
            let src = ti * obs_len;
            batch.observations[dst..dst + obs_len]
                .copy_from_slice(&r.observations[src..src + obs_len]);
        }
        for ti in 0..t {
            let idx = ti * b + bi;
            batch.actions[idx] = r.actions[ti];
            batch.rewards[idx] = r.rewards[ti];
            batch.dones[idx] = r.dones[ti];
            let dst = idx * a;
            batch.behavior_logits[dst..dst + a]
                .copy_from_slice(&r.behavior_logits[ti * a..(ti + 1) * a]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{DType, LeafSpec};
    use std::path::PathBuf;

    fn tiny_manifest(t: usize, b: usize) -> Manifest {
        Manifest {
            dir: PathBuf::new(),
            env: "catch".into(),
            model: "minatar".into(),
            obs_shape: [1, 2, 2],
            num_actions: 3,
            unroll_length: t,
            batch_size: b,
            inference_batch: 4,
            inference_sizes: vec![4],
            param_count: 1,
            params: vec![LeafSpec {
                name: "w".into(),
                shape: vec![1],
                dtype: DType::F32,
            }],
            opt_state: vec![],
            stats_names: vec![],
            hyperparams: crate::util::json::Json::Obj(vec![]),
            hlo_sha256: String::new(),
        }
    }

    fn fill_rollout(r: &mut Rollout, tag: f32) {
        for i in 0..=r.t {
            let obs: Vec<f32> = (0..r.obs_len).map(|k| tag + i as f32 + k as f32 * 0.1).collect();
            r.set_obs(i, &obs);
        }
        for i in 0..r.t {
            let logits: Vec<f32> = (0..r.num_actions).map(|k| tag + k as f32).collect();
            r.set_transition(i, i % r.num_actions, &logits, tag + i as f32, i == r.t - 1);
        }
    }

    #[test]
    fn rollout_fill_and_complete() {
        let mut r = Rollout::new(4, 4, 3);
        assert!(!r.is_complete());
        fill_rollout(&mut r, 10.0);
        assert!(r.is_complete());
        assert_eq!(r.actions, vec![0, 1, 2, 0]);
        assert_eq!(r.dones, vec![0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn roll_over_carries_last_obs() {
        let mut r = Rollout::new(3, 2, 2);
        fill_rollout(&mut r, 0.0);
        let last_obs = r.observations[3 * 2..4 * 2].to_vec();
        r.roll_over();
        assert_eq!(&r.observations[..2], &last_obs[..]);
        assert_eq!(r.filled, 0);
    }

    #[test]
    fn stacking_layout_time_major() {
        let m = tiny_manifest(2, 3);
        let mut rollouts = Vec::new();
        for bi in 0..3 {
            let mut r = Rollout::new(2, 4, 3);
            fill_rollout(&mut r, 100.0 * bi as f32);
            rollouts.push(r);
        }
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&rollouts, &m, &mut batch);
        let (t, b, a, obs_len) = (2, 3, 3, 4);
        for bi in 0..b {
            let tag = 100.0 * bi as f32;
            for ti in 0..t {
                let idx = ti * b + bi;
                assert_eq!(batch.rewards[idx], tag + ti as f32, "reward [{ti},{bi}]");
                assert_eq!(batch.actions[idx], (ti % a) as i32);
                // obs row
                let dst = (ti * b + bi) * obs_len;
                assert_eq!(batch.observations[dst], tag + ti as f32);
                // logits row
                let l = idx * a;
                assert_eq!(batch.behavior_logits[l], tag);
                assert_eq!(batch.behavior_logits[l + 2], tag + 2.0);
            }
            // bootstrap obs at t = T
            let dst = (t * b + bi) * obs_len;
            assert_eq!(batch.observations[dst], tag + t as f32);
        }
    }

    #[test]
    #[should_panic(expected = "need exactly B rollouts")]
    fn stack_wrong_count_panics() {
        let m = tiny_manifest(2, 3);
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&[], &m, &mut batch);
    }

    #[test]
    #[should_panic(expected = "incomplete rollout")]
    fn stack_incomplete_panics() {
        let m = tiny_manifest(2, 1);
        let r = Rollout::new(2, 4, 3);
        let mut batch = LearnerBatch::zeros(&m);
        stack_rollouts(&[r], &m, &mut batch);
    }
}
