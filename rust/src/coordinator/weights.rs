//! Versioned parameter store: learner → inference weight propagation.
//!
//! The learner publishes a host snapshot (`Vec<Vec<f32>>`, manifest
//! leaf order) after every gradient step; the inference thread adopts
//! the newest version before serving the next batch.  The version
//! counter doubles as the staleness signal: the behaviour-policy lag
//! of a rollout is `learner_version - version_used_by_actor`, the
//! quantity V-trace corrects for.  Actors read the counter through a
//! lock-free [`VersionHandle`] to stamp each unroll (DESIGN.md
//! §Sharded-Learner), and the inference thread refreshes through
//! [`copy_newer_into`](WeightsStore::copy_newer_into) — an
//! allocation-free read path that copies into its preallocated host
//! buffers instead of cloning the snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::runtime::ParamVecs;

struct State {
    version: u64,
    params: Arc<ParamVecs>,
    closed: bool,
}

/// Shared store (cheap to clone).
#[derive(Clone)]
pub struct WeightsStore {
    state: Arc<(Mutex<State>, Condvar)>,
    /// Lock-free mirror of `State::version` for hot-path readers
    /// (actors stamping rollouts).  Written under the state lock;
    /// Release-published so a handle read observes a version no newer
    /// than what `latest()` would return.
    version: Arc<AtomicU64>,
}

/// Lock-free read handle on the published weight version.  Actors
/// clone one per thread and stamp every unroll with
/// [`get`](VersionHandle::get); a detached default handle always
/// reads 0 (tests, benches).
#[derive(Clone, Default)]
pub struct VersionHandle(Arc<AtomicU64>);

impl VersionHandle {
    /// Newest published weight version (0 = nothing published yet).
    // tb-lint: no-alloc
    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }
}

impl WeightsStore {
    pub fn new() -> WeightsStore {
        WeightsStore {
            state: Arc::new((
                Mutex::new(State {
                    version: 0,
                    params: Arc::new(Vec::new()),
                    closed: false,
                }),
                Condvar::new(),
            )),
            version: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Publish a new snapshot; returns its version.
    pub fn publish(&self, params: ParamVecs) -> u64 {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        st.version += 1;
        st.params = Arc::new(params);
        self.version.store(st.version, Ordering::Release);
        cv.notify_all();
        st.version
    }

    /// Seed the version counter (checkpoint resume: the monotone
    /// sequence continues from the restored version instead of
    /// resetting to 0).  Must be called before the first `publish`.
    pub fn seed_version(&self, version: u64) {
        let (lock, _) = &*self.state;
        let mut st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        assert!(
            st.version == 0,
            "seed_version after publish would break monotonicity"
        );
        st.version = version;
        self.version.store(version, Ordering::Release);
    }

    /// Latest snapshot (no blocking). Version 0 = nothing published.
    pub fn latest(&self) -> (u64, Arc<ParamVecs>) {
        let (lock, _) = &*self.state;
        let st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        (st.version, st.params.clone())
    }

    /// Copy the latest snapshot into `dst` iff it is newer than
    /// `have`, returning the adopted version.  This is the inference
    /// thread's steady-state refresh: `dst` is its preallocated host
    /// buffer set, so a refresh moves bytes leaf-by-leaf and never
    /// touches the heap (mismatched leaf shapes fall back to a
    /// resizing copy — first adoption only, when `dst` is empty).
    // tb-lint: no-alloc
    pub fn copy_newer_into(&self, have: u64, dst: &mut ParamVecs) -> Option<u64> {
        // cheap lock-free reject: the common case is "nothing new"
        if self.version.load(Ordering::Acquire) <= have {
            return None;
        }
        let (lock, _) = &*self.state;
        let st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        if st.version <= have {
            return None;
        }
        let src: &ParamVecs = &st.params;
        if dst.len() == src.len()
            && dst.iter().zip(src.iter()).all(|(d, s)| d.len() == s.len())
        {
            for (d, s) in dst.iter_mut().zip(src.iter()) {
                d.copy_from_slice(s);
            }
        } else {
            dst.clear();
            // first adoption only: dst is empty / reshaped, so the
            // resizing copy is off the steady-state path
            dst.extend(src.iter().cloned());
        }
        Some(st.version)
    }

    /// Block until a version newer than `than` exists (or closed).
    pub fn wait_newer(&self, than: u64) -> Option<(u64, Arc<ParamVecs>)> {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        loop {
            if st.version > than {
                return Some((st.version, st.params.clone()));
            }
            if st.closed {
                return None;
            }
            st = cv.wait(st).unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        }
    }

    pub fn close(&self) {
        let (lock, cv) = &*self.state;
        lock.lock().unwrap().closed = true; // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        cv.notify_all();
    }

    pub fn version(&self) -> u64 {
        self.state.0.lock().unwrap().version // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
    }

    /// Lock-free version handle for actor-side rollout stamping.
    pub fn handle(&self) -> VersionHandle {
        VersionHandle(self.version.clone())
    }
}

impl Default for WeightsStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn publish_bumps_version() {
        let w = WeightsStore::new();
        assert_eq!(w.version(), 0);
        assert_eq!(w.publish(vec![vec![1.0]]), 1);
        assert_eq!(w.publish(vec![vec![2.0]]), 2);
        let (v, p) = w.latest();
        assert_eq!(v, 2);
        assert_eq!(p[0][0], 2.0);
    }

    #[test]
    fn wait_newer_blocks_then_wakes() {
        let w = WeightsStore::new();
        let w2 = w.clone();
        let waiter = std::thread::spawn(move || w2.wait_newer(0));
        std::thread::sleep(Duration::from_millis(10));
        w.publish(vec![vec![3.0]]);
        let (v, p) = waiter.join().unwrap().unwrap();
        assert_eq!(v, 1);
        assert_eq!(p[0][0], 3.0);
    }

    #[test]
    fn close_releases_waiters() {
        let w = WeightsStore::new();
        let w2 = w.clone();
        let waiter = std::thread::spawn(move || w2.wait_newer(99));
        std::thread::sleep(Duration::from_millis(5));
        w.close();
        assert!(waiter.join().unwrap().is_none());
    }

    #[test]
    fn snapshots_immutable_under_publish() {
        let w = WeightsStore::new();
        w.publish(vec![vec![1.0, 2.0]]);
        let (_, old) = w.latest();
        w.publish(vec![vec![9.0, 9.0]]);
        assert_eq!(old[0], vec![1.0, 2.0], "old snapshot unchanged");
    }

    #[test]
    fn version_handle_tracks_publishes() {
        let w = WeightsStore::new();
        let h = w.handle();
        assert_eq!(h.get(), 0);
        w.publish(vec![vec![1.0]]);
        assert_eq!(h.get(), 1);
        w.publish(vec![vec![2.0]]);
        assert_eq!(h.get(), 2);
        // detached default handle (tests/benches) always reads 0
        assert_eq!(VersionHandle::default().get(), 0);
    }

    #[test]
    fn copy_newer_into_adopts_and_rejects() {
        let w = WeightsStore::new();
        let mut dst: ParamVecs = Vec::new();
        assert_eq!(w.copy_newer_into(0, &mut dst), None, "nothing published");
        w.publish(vec![vec![1.0, 2.0], vec![3.0]]);
        assert_eq!(w.copy_newer_into(0, &mut dst), Some(1), "first adoption");
        assert_eq!(dst, vec![vec![1.0, 2.0], vec![3.0]]);
        assert_eq!(w.copy_newer_into(1, &mut dst), None, "already current");
        w.publish(vec![vec![5.0, 6.0], vec![7.0]]);
        // steady state: same shapes — the copy reuses dst's allocations
        let ptr = dst[0].as_ptr();
        assert_eq!(w.copy_newer_into(1, &mut dst), Some(2));
        assert_eq!(dst, vec![vec![5.0, 6.0], vec![7.0]]);
        assert_eq!(ptr, dst[0].as_ptr(), "refresh must reuse the buffer");
    }

    #[test]
    fn seed_version_continues_monotone() {
        let w = WeightsStore::new();
        w.seed_version(41);
        assert_eq!(w.version(), 41);
        assert_eq!(w.handle().get(), 41);
        assert_eq!(w.publish(vec![vec![1.0]]), 42, "resume continues, no reset");
        // a reader holding the restored version sees the new publish
        let mut dst: ParamVecs = Vec::new();
        assert_eq!(w.copy_newer_into(41, &mut dst), Some(42));
    }
}
