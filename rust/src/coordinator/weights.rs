//! Versioned parameter store: learner → inference weight propagation.
//!
//! The learner publishes a host snapshot (`Vec<Vec<f32>>`, manifest
//! leaf order) after every gradient step; the inference thread adopts
//! the newest version before serving the next batch.  The version
//! counter doubles as the staleness signal: the behaviour-policy lag
//! of a rollout is `learner_version - version_used_by_actor`, the
//! quantity V-trace corrects for.

use std::sync::{Arc, Condvar, Mutex};

use crate::runtime::ParamVecs;

struct State {
    version: u64,
    params: Arc<ParamVecs>,
    closed: bool,
}

/// Shared store (cheap to clone).
#[derive(Clone)]
pub struct WeightsStore {
    state: Arc<(Mutex<State>, Condvar)>,
}

impl WeightsStore {
    pub fn new() -> WeightsStore {
        WeightsStore {
            state: Arc::new((
                Mutex::new(State {
                    version: 0,
                    params: Arc::new(Vec::new()),
                    closed: false,
                }),
                Condvar::new(),
            )),
        }
    }

    /// Publish a new snapshot; returns its version.
    pub fn publish(&self, params: ParamVecs) -> u64 {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        st.version += 1;
        st.params = Arc::new(params);
        cv.notify_all();
        st.version
    }

    /// Latest snapshot (no blocking). Version 0 = nothing published.
    pub fn latest(&self) -> (u64, Arc<ParamVecs>) {
        let (lock, _) = &*self.state;
        let st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        (st.version, st.params.clone())
    }

    /// Block until a version newer than `than` exists (or closed).
    pub fn wait_newer(&self, than: u64) -> Option<(u64, Arc<ParamVecs>)> {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        loop {
            if st.version > than {
                return Some((st.version, st.params.clone()));
            }
            if st.closed {
                return None;
            }
            st = cv.wait(st).unwrap(); // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        }
    }

    pub fn close(&self) {
        let (lock, cv) = &*self.state;
        lock.lock().unwrap().closed = true; // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
        cv.notify_all();
    }

    pub fn version(&self) -> u64 {
        self.state.0.lock().unwrap().version // tb-lint: allow(unwrap, leaf weights lock; poison propagates)
    }
}

impl Default for WeightsStore {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn publish_bumps_version() {
        let w = WeightsStore::new();
        assert_eq!(w.version(), 0);
        assert_eq!(w.publish(vec![vec![1.0]]), 1);
        assert_eq!(w.publish(vec![vec![2.0]]), 2);
        let (v, p) = w.latest();
        assert_eq!(v, 2);
        assert_eq!(p[0][0], 2.0);
    }

    #[test]
    fn wait_newer_blocks_then_wakes() {
        let w = WeightsStore::new();
        let w2 = w.clone();
        let waiter = std::thread::spawn(move || w2.wait_newer(0));
        std::thread::sleep(Duration::from_millis(10));
        w.publish(vec![vec![3.0]]);
        let (v, p) = waiter.join().unwrap().unwrap();
        assert_eq!(v, 1);
        assert_eq!(p[0][0], 3.0);
    }

    #[test]
    fn close_releases_waiters() {
        let w = WeightsStore::new();
        let w2 = w.clone();
        let waiter = std::thread::spawn(move || w2.wait_newer(99));
        std::thread::sleep(Duration::from_millis(5));
        w.close();
        assert!(waiter.join().unwrap().is_none());
    }

    #[test]
    fn snapshots_immutable_under_publish() {
        let w = WeightsStore::new();
        w.publish(vec![vec![1.0, 2.0]]);
        let (_, old) = w.latest();
        w.publish(vec![vec![9.0, 9.0]]);
        assert_eq!(old[0], vec![1.0, 2.0], "old snapshot unchanged");
    }
}
