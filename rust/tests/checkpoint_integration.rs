//! Checkpoint + driver integration: save → resume → continue learning,
//! plus RPC robustness under rude disconnects.

use std::path::{Path, PathBuf};

use torchbeast::config::TrainConfig;
use torchbeast::coordinator;
use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::env::Environment;
use torchbeast::rpc::{EnvServer, RemoteEnv};
use torchbeast::runtime::{checkpoint, LearnerEngine};

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/catch");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/catch missing (run `make artifacts`)");
        None
    }
}

#[test]
fn checkpoint_resume_continues_from_saved_params() {
    let Some(dir) = artifact_dir() else { return };
    let tmp = std::env::temp_dir().join("tb_ckpt_integration");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt = tmp.join("phase1.ckpt");

    // phase 1: short training run, save checkpoint
    let cfg1 = TrainConfig {
        artifact_dir: dir.clone(),
        num_actors: 4,
        total_steps: 8,
        seed: 21,
        log_interval: 0,
        checkpoint_path: Some(ckpt.clone()),
        ..TrainConfig::default()
    };
    let r1 = coordinator::train(&cfg1).unwrap();
    assert!(ckpt.exists());

    // the checkpoint must round-trip exactly, carrying the weight
    // version in effect when it was written: the initial publish plus
    // one publish per learner step
    let learner = LearnerEngine::load(&dir).unwrap();
    let (loaded, saved_version) = checkpoint::load(&ckpt, &learner.manifest).unwrap();
    assert_eq!(loaded, r1.final_params);
    assert_eq!(
        saved_version,
        1 + r1.steps,
        "checkpoint must record the published weight version"
    );

    // phase 2: resume; initial params are the checkpoint, not seed init
    let ckpt2 = tmp.join("phase2.ckpt");
    let cfg2 = TrainConfig {
        artifact_dir: dir.clone(),
        num_actors: 4,
        total_steps: 4,
        seed: 21,
        log_interval: 0,
        init_checkpoint: Some(ckpt.clone()),
        checkpoint_path: Some(ckpt2.clone()),
        ..TrainConfig::default()
    };
    let r2 = coordinator::train(&cfg2).unwrap();
    // resumed run must have moved away from the checkpoint
    assert_ne!(r2.final_params, r1.final_params);
    assert_eq!(r2.steps, 4);
    // and its version sequence must continue monotonically from the
    // saved version (seed_version + initial publish + one per step),
    // not restart from zero
    let (_, resumed_version) = checkpoint::load(&ckpt2, &learner.manifest).unwrap();
    assert_eq!(
        resumed_version,
        saved_version + 1 + r2.steps,
        "resume must continue the weight-version sequence"
    );
}

#[test]
fn evaluate_checkpoint_consistency() {
    let Some(dir) = artifact_dir() else { return };
    let tmp = std::env::temp_dir().join("tb_ckpt_integration2");
    std::fs::create_dir_all(&tmp).unwrap();
    let ckpt = tmp.join("eval.ckpt");
    let mut learner = LearnerEngine::load(&dir).unwrap();
    let params = learner.init_params(33).unwrap();
    checkpoint::save(&ckpt, &learner.manifest, &params, 1).unwrap();
    let (loaded, _version) = checkpoint::load(&ckpt, &learner.manifest).unwrap();
    // greedy eval of identical params must be identical (deterministic env seed)
    let w = torchbeast::env::wrappers::WrapperCfg::default();
    let a = coordinator::evaluate(&dir, &params, 5, 9, &w).unwrap();
    let b = coordinator::evaluate(&dir, &loaded, 5, 9, &w).unwrap();
    assert_eq!(a, b);
}

#[test]
fn env_server_survives_rude_disconnects() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    // several clients connect and vanish without Bye
    for i in 0..5 {
        let env = RemoteEnv::connect(&addr, "catch", i, &WrapperCfg::default()).unwrap();
        std::mem::drop(env); // sends Bye on drop…
        // …and one that is truly rude: raw TCP connect + slam shut
        let s = std::net::TcpStream::connect(&addr).unwrap();
        drop(s);
    }
    // server still serves a fresh stream correctly
    let mut env = RemoteEnv::connect(&addr, "catch", 99, &WrapperCfg::default()).unwrap();
    let mut obs = vec![0.0; env.spec().obs_len()];
    env.reset(&mut obs);
    let mut done_seen = false;
    for i in 0..30 {
        if env.step(i % 3, &mut obs).done {
            done_seen = true;
            break;
        }
    }
    assert!(done_seen);
}

#[test]
fn train_with_env_cost_still_learns_shape() {
    // env_cost wrapper on the training path (E2's expensive-env knob)
    let Some(dir) = artifact_dir() else { return };
    let mut cfg = TrainConfig {
        artifact_dir: dir,
        num_actors: 4,
        total_steps: 5,
        seed: 2,
        log_interval: 0,
        ..TrainConfig::default()
    };
    cfg.wrappers.env_cost_us = 200;
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 5);
    assert!(report.history.iter().all(|r| r.stats.total_loss().is_finite()));
}

#[test]
fn runtime_frame_stack_rejected() {
    // frame_stack must be baked into artifacts, not wrapped at runtime
    let Some(dir) = artifact_dir() else { return };
    let mut cfg = TrainConfig {
        artifact_dir: dir,
        total_steps: 1,
        ..TrainConfig::default()
    };
    cfg.wrappers.frame_stack = 4;
    let err = match coordinator::train(&cfg) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("runtime frame_stack should be rejected"),
    };
    assert!(err.contains("frame_stack"), "{err}");
}
