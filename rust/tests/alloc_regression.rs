//! Allocation-regression gate for the full experience path.
//!
//! `benches/batcher.rs` audits the inference batcher in isolation;
//! this test drives the *entire* actor loop — wrapped env step →
//! pooled inference slot → in-place softmax sampling → pooled rollout
//! buffer → learner queue → time-major stacking → pool recycle — under
//! the counting global allocator and asserts the steady state performs
//! **zero** heap allocations per env step and per rollout handoff.
//! Span-ring tracing is switched on for the measured windows, so the
//! tracer's record path (histogram + ring push) rides inside the same
//! zero budget (DESIGN.md §Tracing).
//!
//! Run explicitly (scripts/ci.sh does):
//!     cargo test --release --test alloc_regression
//!
//! The global allocator is per test binary, so this lives in its own
//! integration-test crate.

use std::time::Duration;

use torchbeast::coordinator::actor_pool::{ActorConfig, ActorPool};
use torchbeast::coordinator::batching_queue::batching_queue;
use torchbeast::coordinator::dynamic_batcher::{dynamic_batcher, BatcherConfig};
use torchbeast::coordinator::rollout::{stack_rollouts, Rollout, RolloutPool};
use torchbeast::coordinator::weights::VersionHandle;
use torchbeast::env::wrappers::{wrapped_spec, WrapperCfg};
use torchbeast::env::{self, Environment};
use torchbeast::metrics::Metrics;
use torchbeast::rpc::{EnvServer, RemoteEnv};
use torchbeast::telemetry::gauges::Counter;
use torchbeast::runtime::manifest::{DType, LeafSpec};
use torchbeast::runtime::{LearnerBatch, Manifest};
use torchbeast::util::counting_alloc::{allocations, CountingAllocator};

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// The allocation counter is process-global and cargo runs tests in
/// parallel, so every test in this binary takes this lock: another
/// test's setup/teardown allocations must not land in a measuring
/// window.
static MEASURE_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

const UNROLL: usize = 5;
const BATCH: usize = 2;
const ACTORS: usize = 2;
/// Batches consumed before the measuring window opens: enough frames
/// to fill the metrics return ring (100 catch episodes ≈ 900 frames)
/// and warm every pool.
const WARMUP_BATCHES: usize = 150;
const MEASURE_BATCHES: usize = 100;

fn stub_manifest(obs_shape: [usize; 3], num_actions: usize) -> Manifest {
    Manifest {
        dir: std::path::PathBuf::new(),
        env: "catch".into(),
        model: "stub".into(),
        obs_shape,
        num_actions,
        unroll_length: UNROLL,
        batch_size: BATCH,
        inference_batch: ACTORS,
        inference_sizes: vec![ACTORS],
        param_count: 1,
        params: vec![LeafSpec {
            name: "w".into(),
            shape: vec![1],
            dtype: DType::F32,
        }],
        opt_state: vec![],
        stats_names: vec![],
        hyperparams: torchbeast::util::json::Json::Obj(vec![]),
        hlo_sha256: String::new(),
    }
}

/// The paper's §5.1 claim, measured end to end: after warm-up, the
/// mono experience path must not touch the heap at all.
#[test]
fn actor_to_learner_path_is_allocation_free_at_steady_state() {
    let _serial = MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // measure the fully traced path: with ring buffering on, every
    // ActorUnroll/EnvStep span also appends to its thread's span ring
    // (registration allocates once per thread, during warm-up)
    torchbeast::telemetry::trace::set_ring_buffering(true);
    // frame_stack = 2 exercises the FrameStack ring's in-place writes
    // (it used to allocate a scratch Vec per env step)
    let wrappers = WrapperCfg {
        frame_stack: 2,
        ..WrapperCfg::default()
    };
    let base = env::spec_of("catch").unwrap();
    let spec = wrapped_spec(&base, &wrappers);
    let obs_len = spec.obs_len();
    let num_actions = spec.num_actions;
    let manifest = stub_manifest(spec.obs_shape(), num_actions);

    let (client, stream) = dynamic_batcher(
        BatcherConfig::new(ACTORS, Duration::from_micros(500), obs_len, num_actions)
            .with_slots(ACTORS),
    );
    let (tx, rx) = batching_queue::<Rollout>(2 * BATCH);
    let buffers = RolloutPool::new(ACTORS + 2 * BATCH + BATCH, UNROLL, obs_len, num_actions);
    let metrics = Metrics::shared();

    // stub inference thread: preallocated uniform logits, pooled respond
    let infer_thread = std::thread::spawn(move || {
        let logits = vec![0.0f32; ACTORS * num_actions];
        let baselines = vec![0.0f32; ACTORS];
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            batch
                .respond(&logits[..n * num_actions], &baselines[..n], num_actions)
                .unwrap();
        }
    });

    let envs: Vec<Box<dyn Environment>> = (0..ACTORS)
        .map(|i| env::make_wrapped("catch", i as u64, &wrappers).unwrap())
        .collect();
    let pool = ActorPool::spawn(
        envs,
        client.clone(),
        tx.clone(),
        buffers.clone(),
        metrics.clone(),
        ActorConfig {
            unroll_length: UNROLL,
            num_actions,
            obs_len,
            seed: 5,
            first_id: 0,
            policy_version: VersionHandle::default(),
            heartbeat: Counter::default(),
        },
    );

    // learner-side stacker: preallocated batch + rollout buffer, recycle
    let mut batch = LearnerBatch::zeros(&manifest);
    let mut rollouts: Vec<Rollout> = Vec::with_capacity(BATCH);
    let consume = |n: usize, rollouts: &mut Vec<Rollout>, batch: &mut LearnerBatch| {
        for _ in 0..n {
            assert!(rx.recv_batch_into(BATCH, rollouts), "pipeline died early");
            stack_rollouts(rollouts, &manifest, batch);
            for r in rollouts.drain(..) {
                buffers.recycle(r);
            }
        }
    };

    consume(WARMUP_BATCHES, &mut rollouts, &mut batch);
    let a0 = allocations();
    consume(MEASURE_BATCHES, &mut rollouts, &mut batch);
    let allocs = allocations() - a0;

    let frames = (MEASURE_BATCHES * BATCH * UNROLL) as f64;
    let handoffs = (MEASURE_BATCHES * BATCH) as f64;
    let per_frame = allocs as f64 / frames;
    let per_handoff = allocs as f64 / handoffs;
    eprintln!(
        "steady state: {allocs} heap allocations over {frames} env steps \
         ({per_frame:.4}/step, {per_handoff:.4}/rollout handoff)"
    );
    // the budget is zero; < 0.02/step tolerates nothing but one-off
    // noise (a single stray warm-up straggler) over 1000 steps
    assert!(
        per_frame < 0.02,
        "experience hot path is allocating again: {per_frame:.4} allocs per env step"
    );

    // orderly shutdown: no deadlock with pooled buffers in flight
    rx.close();
    buffers.close();
    client.shutdown_for_tests();
    let exits = pool.join();
    infer_thread.join().unwrap();
    torchbeast::telemetry::trace::set_ring_buffering(false);
    assert_eq!(exits.len(), ACTORS);
    let produced: u64 = exits
        .iter()
        .map(|e| e.report().expect("actor completed").rollouts)
        .sum();
    assert!(produced as usize >= WARMUP_BATCHES + MEASURE_BATCHES);
}

/// The poly half of the same claim (ROADMAP): remote observations
/// deserialize through the rpc codec straight into the actor's obs
/// buffer, so a localhost poly pipeline must be just as
/// allocation-free as the mono one.  The env servers run in this
/// process and share the counting allocator, so both ends of the wire
/// are measured: client `write_action`/`read_frame`/
/// `decode_observation_into` and the server's reused frame buffers.
#[test]
fn poly_actor_path_is_allocation_free_at_steady_state() {
    let _serial = MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let wrappers = WrapperCfg::default();
    let spec = env::spec_of("catch").unwrap();
    let obs_len = spec.obs_len();
    let num_actions = spec.num_actions;
    let manifest = stub_manifest(spec.obs_shape(), num_actions);

    let mut server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();

    let (client, stream) = dynamic_batcher(
        BatcherConfig::new(ACTORS, Duration::from_micros(500), obs_len, num_actions)
            .with_slots(ACTORS),
    );
    let (tx, rx) = batching_queue::<Rollout>(2 * BATCH);
    let buffers = RolloutPool::new(ACTORS + 2 * BATCH + BATCH, UNROLL, obs_len, num_actions);
    let metrics = Metrics::shared();

    let infer_thread = std::thread::spawn(move || {
        let logits = vec![0.0f32; ACTORS * num_actions];
        let baselines = vec![0.0f32; ACTORS];
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            batch
                .respond(&logits[..n * num_actions], &baselines[..n], num_actions)
                .unwrap();
        }
    });

    let envs: Vec<Box<dyn Environment>> = (0..ACTORS)
        .map(|i| {
            Box::new(RemoteEnv::connect(&addr, "catch", i as u64, &wrappers).unwrap())
                as Box<dyn Environment>
        })
        .collect();
    let pool = ActorPool::spawn(
        envs,
        client.clone(),
        tx.clone(),
        buffers.clone(),
        metrics.clone(),
        ActorConfig {
            unroll_length: UNROLL,
            num_actions,
            obs_len,
            seed: 11,
            first_id: 0,
            policy_version: VersionHandle::default(),
            heartbeat: Counter::default(),
        },
    );

    let mut batch = LearnerBatch::zeros(&manifest);
    let mut rollouts: Vec<Rollout> = Vec::with_capacity(BATCH);
    let consume = |n: usize, rollouts: &mut Vec<Rollout>, batch: &mut LearnerBatch| {
        for _ in 0..n {
            assert!(rx.recv_batch_into(BATCH, rollouts), "pipeline died early");
            stack_rollouts(rollouts, &manifest, batch);
            for r in rollouts.drain(..) {
                buffers.recycle(r);
            }
        }
    };

    consume(WARMUP_BATCHES, &mut rollouts, &mut batch);
    let a0 = allocations();
    consume(MEASURE_BATCHES, &mut rollouts, &mut batch);
    let allocs = allocations() - a0;

    let frames = (MEASURE_BATCHES * BATCH * UNROLL) as f64;
    let per_frame = allocs as f64 / frames;
    eprintln!(
        "poly steady state: {allocs} heap allocations over {frames} env steps \
         ({per_frame:.4}/step through the rpc codec)"
    );
    // Same zero budget as the mono test.  The only legitimate stragglers
    // are anyhow-boxed server read-timeouts, which need a 200 ms stall
    // mid-measurement to occur at all.
    assert!(
        per_frame < 0.02,
        "poly experience path is allocating again: {per_frame:.4} allocs per env step"
    );

    rx.close();
    buffers.close();
    client.shutdown_for_tests();
    let exits = pool.join();
    infer_thread.join().unwrap();
    server.shutdown();
    assert_eq!(exits.len(), ACTORS);
    let produced: u64 = exits
        .iter()
        .map(|e| e.report().expect("actor completed").rollouts)
        .sum();
    assert!(produced as usize >= WARMUP_BATCHES + MEASURE_BATCHES);
    assert!(
        server
            .steps_served
            .load(std::sync::atomic::Ordering::Relaxed)
            > 0
    );
}

/// The batched (VecEnv) half of the poly claim: a whole group's step
/// — one `ActionBatch` out, one `ObsBatch` back, B envs stepped —
/// must allocate nothing at steady state on *either* codec end.  The
/// vectorized `EnvServer` runs in this process under the same
/// counting allocator, so the gate covers `write_action_batch` /
/// `read_frame` / `decode_obs_batch_into` client-side AND the
/// server's reused frame/obs-block buffers.
#[test]
fn batched_remote_step_is_allocation_free_at_steady_state() {
    use torchbeast::env::{SlotStep, VecEnvironment};
    use torchbeast::rpc::RemoteVecEnv;

    let _serial = MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let wrappers = WrapperCfg::default();
    let spec = env::spec_of("catch").unwrap();
    let obs_len = spec.obs_len();
    let b = 8usize;
    let seeds: Vec<u64> = (0..b as u64).collect();

    let mut server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut venv = RemoteVecEnv::connect(&addr, "catch", &seeds, &wrappers).unwrap();
    assert_eq!(venv.batch(), b);

    let mut obs_block = vec![0.0f32; b * obs_len];
    let mut steps = vec![SlotStep::default(); b];
    let mut actions = vec![0usize; b];
    venv.reset_all(&mut obs_block);

    let warmup = 500;
    let measure = 500;
    for round in 0..warmup {
        for (s, a) in actions.iter_mut().enumerate() {
            *a = (round + s) % spec.num_actions;
        }
        venv.step_batch(&actions, &mut obs_block, &mut steps);
    }
    let a0 = allocations();
    for round in 0..measure {
        for (s, a) in actions.iter_mut().enumerate() {
            *a = (round + s) % spec.num_actions;
        }
        venv.step_batch(&actions, &mut obs_block, &mut steps);
    }
    let allocs = allocations() - a0;
    let frames = (measure * b) as f64;
    let per_round = allocs as f64 / measure as f64;
    let per_frame = allocs as f64 / frames;
    eprintln!(
        "batched steady state: {allocs} heap allocations over {measure} group rounds \
         of {b} envs ({per_round:.4}/round, {per_frame:.4}/env step, both codec ends)"
    );
    assert!(
        per_round < 0.02,
        "batched rpc step path is allocating again: {per_round:.4} allocs per group round"
    );
    assert!(venv.last_error().is_none(), "{:?}", venv.last_error());

    drop(venv);
    server.shutdown();
    assert_eq!(
        server
            .steps_served
            .load(std::sync::atomic::Ordering::Relaxed),
        (warmup + measure) as u64 * b as u64
    );
}

/// The replay tentpole's zero-alloc claim (DESIGN.md §Replay): at
/// steady state the full mixed-batch round — plan → sample from the
/// ring → stack fresh + replayed columns → copy fresh rollouts in
/// place into ring slots (FIFO-evicting) — must not touch the heap.
/// Slots are preallocated at construction, exactly like the
/// `RolloutPool`.
#[test]
fn replay_insert_sample_stack_path_is_allocation_free_at_steady_state() {
    use torchbeast::coordinator::replay::{stack_mixed, ReplayBuffer};

    let _serial = MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let spec = env::spec_of("catch").unwrap();
    let obs_len = spec.obs_len();
    let num_actions = spec.num_actions;
    let manifest = stub_manifest(spec.obs_shape(), num_actions);
    let b = manifest.batch_size;
    let ratio = 0.5;

    // complete "fresh" rollouts to circulate (contents irrelevant to
    // the gate; shapes match the manifest)
    let mut fresh: Vec<Rollout> = (0..b)
        .map(|k| {
            let mut r = Rollout::new(UNROLL, obs_len, num_actions);
            let obs = vec![k as f32; obs_len];
            let logits = vec![0.5; num_actions];
            for i in 0..=UNROLL {
                r.set_obs(i, &obs);
            }
            for i in 0..UNROLL {
                r.set_transition(i, i % num_actions, &logits, 0.0, i == UNROLL - 1);
            }
            r
        })
        .collect();
    let mut replay = ReplayBuffer::new(16, UNROLL, obs_len, num_actions, 7);
    let mut batch = LearnerBatch::zeros(&manifest);

    // one mixed round, exactly the driver's stacker shape
    let mut round = |replay: &mut ReplayBuffer, fresh: &[Rollout]| {
        let replayed = replay.plan(b, ratio);
        let fresh_n = b - replayed;
        stack_mixed(&fresh[..fresh_n], replay, replayed, &manifest, &mut batch);
        for r in &fresh[..fresh_n] {
            replay.insert(r);
        }
    };

    // warm: fill the ring and spill past capacity (eviction path too)
    for _ in 0..32 {
        round(&mut replay, &fresh);
    }
    assert!(replay.warmed_up());
    assert!(replay.stats().evicted > 0, "the eviction path must be warm");

    let rounds = 500usize;
    let a0 = allocations();
    for _ in 0..rounds {
        round(&mut replay, &fresh);
    }
    let allocs = allocations() - a0;
    let per_round = allocs as f64 / rounds as f64;
    eprintln!(
        "replay steady state: {allocs} heap allocations over {rounds} mixed rounds \
         of {b} columns ({per_round:.4}/round: plan + sample + stack + insert)"
    );
    assert!(
        per_round < 0.02,
        "replay insert/sample/stack path is allocating again: {per_round:.4} per round"
    );
    let s = replay.stats();
    assert!(s.sampled > 0, "the gate must actually exercise sampling");
    assert_eq!(s.len, 16, "the ring stays at capacity");

    // Phase 2 — the stamped → staleness-checked path (DESIGN.md
    // §Sharded-Learner): fresh rollouts now carry an advancing policy
    // version, the ring learns the current version before every plan,
    // and slots more than K versions old are tail-evicted.  The whole
    // stamp → evict → probe → stack → insert round must be just as
    // allocation-free as the unbounded one.
    let staleness = 6u64;
    replay.set_staleness(staleness);
    let mut stale_round = |replay: &mut ReplayBuffer, fresh: &mut [Rollout], version: u64| {
        for r in fresh.iter_mut() {
            r.policy_version = version;
        }
        replay.set_current_version(version);
        let replayed = replay.plan(b, ratio);
        let fresh_n = b - replayed;
        stack_mixed(&fresh[..fresh_n], replay, replayed, &manifest, &mut batch);
        for r in &fresh[..fresh_n] {
            replay.insert(r);
        }
    };

    // warm: advance far enough that the phase-1 slots (all version 0)
    // and a few stamped generations age out through the stale path
    let mut version = 0u64;
    for _ in 0..32 {
        version += 1;
        stale_round(&mut replay, &mut fresh, version);
    }
    let warm_stale = replay.stats().stale_evicted;
    assert!(warm_stale > 0, "the staleness-eviction path must be warm");

    let a0 = allocations();
    for _ in 0..rounds {
        version += 1;
        stale_round(&mut replay, &mut fresh, version);
    }
    let allocs = allocations() - a0;
    let per_round = allocs as f64 / rounds as f64;
    eprintln!(
        "staleness steady state: {allocs} heap allocations over {rounds} stamped rounds \
         ({per_round:.4}/round: stamp + evict + plan + sample + stack + insert)"
    );
    assert!(
        per_round < 0.02,
        "staleness-checked replay path is allocating again: {per_round:.4} per round"
    );
    let s = replay.stats();
    assert!(
        s.stale_evicted > warm_stale,
        "measured rounds must keep exercising stale eviction"
    );
    // one insert and one version bump per round: the ring holds exactly
    // the last K+1 generations at steady state, and after eviction
    // every held slot is sampleable (fresh)
    assert_eq!(s.len, staleness as usize + 1, "ring is staleness-bounded");
    assert_eq!(replay.fresh_len(), s.len, "no stale slot survives eviction");
}

/// The policy-serving tentpole's zero-alloc claim (DESIGN.md
/// §Policy-Server): at steady state one served round — ObsBatch decode
/// → bounded slice submit → stub inference → per-slot action sampling
/// → ActionBatch respond → latency-histogram record — must not touch
/// the heap on either end of the socket.
#[test]
fn served_inference_round_is_allocation_free_at_steady_state() {
    use torchbeast::serving::{
        run_inference_loop, PolicyClient, PolicyServer, PolicyServerConfig,
    };
    use torchbeast::telemetry::gauges::PipelineGauges;

    let _serial = MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // span the serve rounds into the tracer's rings too: the per-round
    // ring push must be as allocation-free as the round itself (the
    // one-time per-thread ring registration lands in warm-up)
    torchbeast::telemetry::trace::set_ring_buffering(true);
    const B: usize = 4;
    let obs_len = 6usize;
    let num_actions = 4usize;

    let gauges = PipelineGauges::shared();
    let cfg = PolicyServerConfig::new([1, 2, 3], num_actions, B)
        .with_batch_timeout(Duration::from_micros(100));
    let mut server =
        PolicyServer::start_with_gauges("127.0.0.1:0", cfg, gauges.clone()).unwrap();
    let stream = server.take_batch_stream().unwrap();
    // stub backend: logits derived from the obs, buffers reused every
    // batch (clear + push within warmed capacity)
    let backend = std::thread::spawn(move || {
        run_inference_loop(&stream, num_actions, move |obs, n, logits, baselines| {
            logits.clear();
            baselines.clear();
            for k in 0..n {
                for a in 0..num_actions {
                    logits.push(obs[k * obs_len] * 0.01 + a as f32 * 0.1);
                }
                baselines.push(0.0);
            }
            Ok(())
        })
        .unwrap();
    });

    let addr = server.addr.to_string();
    let seeds: Vec<u64> = (0..B as u64).collect();
    let mut client = PolicyClient::connect(&[addr], &seeds).unwrap();
    let mut obs = vec![0.0f32; B * obs_len];
    let mut actions = vec![0usize; B];

    let warmup = 300;
    let measure = 500;
    for round in 0..warmup {
        for (i, x) in obs.iter_mut().enumerate() {
            *x = ((round + i) % 17) as f32 * 0.05;
        }
        client.act(&obs, &mut actions).unwrap();
    }
    let a0 = allocations();
    for round in 0..measure {
        for (i, x) in obs.iter_mut().enumerate() {
            *x = ((round + i) % 17) as f32 * 0.05;
        }
        client.act(&obs, &mut actions).unwrap();
    }
    let allocs = allocations() - a0;
    let per_round = allocs as f64 / measure as f64;
    eprintln!(
        "serve loop steady state: {allocs} heap allocations over {measure} served rounds \
         of {B} slots ({per_round:.4}/round, both socket ends + histogram record)"
    );
    assert!(
        per_round < 0.02,
        "policy serve loop is allocating again: {per_round:.4} allocs per served round"
    );

    drop(client);
    server.shutdown();
    backend.join().unwrap();
    torchbeast::telemetry::trace::set_ring_buffering(false);
    let snap = gauges.snapshot();
    assert_eq!(snap.serve_requests, (warmup + measure) as u64);
    assert_eq!(snap.serve_busy, 0, "a lone stream must never draw Busy");
}

/// Rollout handoff ships the pooled buffer itself: the backing
/// allocation the learner side receives is the very allocation the
/// actor filled (no clone anywhere in between).
#[test]
fn rollout_handoff_moves_the_buffer_not_a_copy() {
    let _serial = MEASURE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let spec = env::spec_of("catch").unwrap();
    let obs_len = spec.obs_len();
    let (client, stream) = dynamic_batcher(BatcherConfig::new(
        1,
        Duration::from_micros(100),
        obs_len,
        spec.num_actions,
    ));
    let (tx, rx) = batching_queue::<Rollout>(4);
    let buffers = RolloutPool::new(2, UNROLL, obs_len, spec.num_actions);
    // observe the pooled buffers' backing pointers before the run
    let probe_a = buffers.rent().unwrap();
    let probe_b = buffers.rent().unwrap();
    let pooled_ptrs = [probe_a.observations.as_ptr(), probe_b.observations.as_ptr()];
    buffers.recycle(probe_a);
    buffers.recycle(probe_b);

    let na = spec.num_actions;
    let infer_thread = std::thread::spawn(move || {
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            batch.respond(&vec![0.0; n * na], &vec![0.0; n], na).unwrap();
        }
    });
    let pool = ActorPool::spawn(
        vec![env::make_env("catch", 1).unwrap()],
        client.clone(),
        tx,
        buffers.clone(),
        Metrics::shared(),
        ActorConfig {
            unroll_length: UNROLL,
            num_actions: spec.num_actions,
            obs_len,
            seed: 3,
            first_id: 0,
            policy_version: VersionHandle::default(),
            heartbeat: Counter::default(),
        },
    );
    for _ in 0..4 {
        let r = rx.recv_batch(1).unwrap().remove(0);
        assert!(
            pooled_ptrs.contains(&r.observations.as_ptr()),
            "received rollout must be a pooled buffer, not a clone"
        );
        buffers.recycle(r);
    }
    rx.close();
    buffers.close();
    client.shutdown_for_tests();
    pool.join();
    infer_thread.join().unwrap();
}
