//! Fault-injection suite for the run-supervision layer (DESIGN.md
//! §Supervision): actor restart with backoff, pipeline watchdog, and
//! verified crash-safe checkpoints.
//!
//! All tests are component-level (dynamic batcher + stub inference
//! thread + batching queue — no XLA artifacts), mirroring the
//! actor-pool harness: faults are injected through `Environment`
//! wrappers that panic on cue, and the assertions pin the supervision
//! *contracts*:
//!
//! 1. a respawned actor (panic before its first shipped rollout)
//!    reproduces the unsupervised run bit-for-bit — same env seed,
//!    same sampling-RNG seed, same version handle;
//! 2. budget exhaustion degrades gracefully: survivors keep producing,
//!    every exit is typed, and the death of the *last* actor closes
//!    the learner queue instead of deadlocking the run;
//! 3. a wedged stage trips the watchdog, whose diagnosis names the
//!    silent stage, and the escalation path writes a loadable
//!    emergency checkpoint while unblocking the pipeline;
//! 4. a bit-flipped checkpoint blob is rejected *by name* and resume
//!    falls back to the newest intact retained generation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use torchbeast::coordinator::actor_pool::{ActorConfig, ActorExit, ActorPool};
use torchbeast::coordinator::batching_queue::batching_queue;
use torchbeast::coordinator::dynamic_batcher::{dynamic_batcher, BatcherConfig, InferenceClient};
use torchbeast::coordinator::rollout::{Rollout, RolloutPool};
use torchbeast::coordinator::supervisor::{
    EnvFactory, HeartbeatRegistry, SupervisedActors, SupervisorConfig, Watchdog,
};
use torchbeast::coordinator::weights::VersionHandle;
use torchbeast::env::{self, Environment, EnvSpec, Step};
use torchbeast::metrics::Metrics;
use torchbeast::runtime::checkpoint::{self, CheckpointError};
use torchbeast::runtime::manifest::{DType, LeafSpec};
use torchbeast::runtime::{Manifest, ParamVecs};
use torchbeast::telemetry::gauges::{Counter, PipelineGauges};

const T: usize = 5;
const ENV_SEED: u64 = 123;
const RNG_SEED: u64 = 7;

// ---------------------------------------------------------------------------
// fault-injecting environments
// ---------------------------------------------------------------------------

/// Delegating wrapper that panics on its `panic_at`-th step while the
/// shared fuse is armed (the panic disarms it, so the respawned env —
/// rebuilt around the same fuse — runs clean).  With the fuse disarmed
/// from the start it is a transparent pass-through, so the supervised
/// run's trajectory is comparable to an unsupervised one.
struct PanicOnce {
    inner: Box<dyn Environment>,
    fuse: Arc<AtomicBool>,
    panic_at: u32,
    steps: u32,
}

impl PanicOnce {
    fn new(inner: Box<dyn Environment>, fuse: Arc<AtomicBool>, panic_at: u32) -> PanicOnce {
        PanicOnce {
            inner,
            fuse,
            panic_at,
            steps: 0,
        }
    }
}

impl Environment for PanicOnce {
    fn spec(&self) -> &EnvSpec {
        self.inner.spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.inner.reset(obs)
    }

    fn step(&mut self, action: usize, obs: &mut [f32]) -> Step {
        self.steps += 1;
        if self.steps >= self.panic_at && self.fuse.swap(false, Ordering::SeqCst) {
            panic!("injected actor fault at step {}", self.steps);
        }
        self.inner.step(action, obs)
    }

    fn reseed(&mut self, seed: u64) {
        self.inner.reseed(seed)
    }
}

/// An env that panics on every step of every life: exhausts any
/// restart budget.
struct AlwaysPanic {
    inner: Box<dyn Environment>,
}

impl Environment for AlwaysPanic {
    fn spec(&self) -> &EnvSpec {
        self.inner.spec()
    }

    fn reset(&mut self, obs: &mut [f32]) {
        self.inner.reset(obs)
    }

    fn step(&mut self, _action: usize, _obs: &mut [f32]) -> Step {
        panic!("injected permanent fault");
    }

    fn reseed(&mut self, seed: u64) {
        self.inner.reseed(seed)
    }
}

// ---------------------------------------------------------------------------
// harness
// ---------------------------------------------------------------------------

/// Obs-keyed deterministic inference stub (position-weighted pixel
/// sum → one-hot logits): the sampled action depends on the
/// observation contents, so any divergence between a restarted actor
/// and the reference run changes trajectories and trips the
/// bit-identity assertions.
fn obs_keyed_inference(
    stream: torchbeast::coordinator::dynamic_batcher::BatchStream,
    obs_len: usize,
    num_actions: usize,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            let obs = batch.obs_flat();
            let mut logits = vec![0.0f32; n * num_actions];
            for j in 0..n {
                let row = &obs[j * obs_len..(j + 1) * obs_len];
                let hot = row
                    .iter()
                    .enumerate()
                    .map(|(i, &v)| (i + 1) * (v as usize))
                    .sum::<usize>()
                    % num_actions;
                logits[j * num_actions + hot] = 2.0;
            }
            batch
                .respond(&logits, &vec![0.0; n], num_actions)
                .unwrap();
        }
    })
}

/// Everything one pipeline run needs, pre-wired for `n_actors`.
struct Rig {
    client: InferenceClient,
    tx: torchbeast::coordinator::batching_queue::QueueSender<Rollout>,
    rx: torchbeast::coordinator::batching_queue::QueueReceiver<Rollout>,
    buffers: RolloutPool,
    metrics: Arc<Metrics>,
    gauges: Arc<PipelineGauges>,
    infer: std::thread::JoinHandle<()>,
    spec: EnvSpec,
}

fn rig(n_actors: usize) -> Rig {
    let spec = env::spec_of("catch").unwrap();
    let (client, stream) = dynamic_batcher(BatcherConfig::new(
        n_actors,
        Duration::from_micros(500),
        spec.obs_len(),
        spec.num_actions,
    ));
    let infer = obs_keyed_inference(stream, spec.obs_len(), spec.num_actions);
    let (tx, rx) = batching_queue::<Rollout>(8);
    let gauges = PipelineGauges::shared();
    let buffers = RolloutPool::with_gauges(
        n_actors + 9,
        T,
        spec.obs_len(),
        spec.num_actions,
        gauges.clone(),
    );
    Rig {
        client,
        tx,
        rx,
        buffers,
        metrics: Metrics::shared(),
        gauges,
        infer,
        spec,
    }
}

fn actor_cfg(spec: &EnvSpec) -> ActorConfig {
    ActorConfig {
        unroll_length: T,
        num_actions: spec.num_actions,
        obs_len: spec.obs_len(),
        seed: RNG_SEED,
        first_id: 0,
        policy_version: VersionHandle::default(),
        heartbeat: Counter::default(),
    }
}

/// The bits of a rollout that define the trajectory.
type Captured = (Vec<f32>, Vec<i32>, Vec<f32>, Vec<f32>);

fn capture(r: &Rollout) -> Captured {
    (
        r.observations.clone(),
        r.actions.clone(),
        r.rewards.clone(),
        r.dones.clone(),
    )
}

/// Drain `n` rollouts (recycling buffers like the stacker does), then
/// shut the rig down and return the captures plus the typed exits.
fn collect_and_shutdown(
    rig: Rig,
    n: usize,
    join: impl FnOnce() -> Vec<ActorExit>,
) -> (Vec<Captured>, Vec<ActorExit>) {
    let mut got = Vec::with_capacity(n);
    while got.len() < n {
        let rollouts = rig.rx.recv_batch(1).expect("pipeline died early");
        for r in &rollouts {
            got.push(capture(r));
        }
        for r in rollouts {
            rig.buffers.recycle(r);
        }
    }
    rig.rx.close();
    rig.client.shutdown_for_tests();
    rig.buffers.close();
    let exits = join();
    rig.infer.join().unwrap();
    (got, exits)
}

// ---------------------------------------------------------------------------
// 1. respawn determinism
// ---------------------------------------------------------------------------

/// A supervised actor that panics *before shipping its first rollout*
/// and is respawned must reproduce the unsupervised run bit-for-bit:
/// the factory rebuilds the same env (name, seed, wrappers), and the
/// actor loop restarts its sampling RNG from the same derived seed.
#[test]
fn respawned_actor_is_bit_identical_to_unsupervised_run() {
    const ROLLOUTS: usize = 4;

    // reference: classic pool, no fault
    let reference = {
        let rg = rig(1);
        let envs: Vec<Box<dyn Environment>> = vec![env::make_env("catch", ENV_SEED).unwrap()];
        let pool = ActorPool::spawn(
            envs,
            rg.client.clone(),
            rg.tx.clone(),
            rg.buffers.clone(),
            rg.metrics.clone(),
            actor_cfg(&rg.spec),
        );
        let (got, exits) = collect_and_shutdown(rg, ROLLOUTS, move || pool.join());
        assert!(exits[0].report().is_some(), "reference run must not panic");
        got
    };

    // supervised: panic injected at step 3 of the first life (< T, so
    // nothing has shipped), restart budget 1
    let supervised = {
        let rg = rig(1);
        let fuse = Arc::new(AtomicBool::new(true));
        let first = Box::new(PanicOnce::new(
            env::make_env("catch", ENV_SEED).unwrap(),
            fuse.clone(),
            3,
        )) as Box<dyn Environment>;
        let factory_fuse = fuse.clone();
        let factory: EnvFactory = Box::new(move || {
            Ok(Box::new(PanicOnce::new(
                env::make_env("catch", ENV_SEED)?,
                factory_fuse.clone(),
                3,
            )) as Box<dyn Environment>)
        });
        let gauges = rg.gauges.clone();
        let pool = SupervisedActors::spawn(
            vec![(first, factory)],
            rg.client.clone(),
            rg.tx.clone(),
            rg.buffers.clone(),
            rg.metrics.clone(),
            actor_cfg(&rg.spec),
            SupervisorConfig {
                max_restarts: 1,
                backoff: Duration::from_millis(1),
            },
            gauges.clone(),
        );
        let (got, exits) = collect_and_shutdown(rg, ROLLOUTS, move || pool.join());
        assert!(!fuse.load(Ordering::SeqCst), "the injected fault must fire");
        assert_eq!(gauges.actor_panics.get(), 1);
        assert_eq!(gauges.actor_restarts.get(), 1);
        assert_eq!(gauges.actors_lost.get(), 0);
        let report = exits[0].report().expect("restarted actor completes");
        assert!(report.rollouts >= ROLLOUTS as u64);
        got
    };

    assert_eq!(reference.len(), supervised.len());
    for (k, (a, b)) in reference.iter().zip(&supervised).enumerate() {
        assert_eq!(a, b, "rollout {k} must be bit-identical after respawn");
    }
}

// ---------------------------------------------------------------------------
// 2. budget exhaustion
// ---------------------------------------------------------------------------

/// A permanently-faulty actor exhausts its restart budget and is lost;
/// the surviving actor keeps the run alive and every exit is typed.
#[test]
fn budget_exhaustion_keeps_survivors_flowing() {
    let rg = rig(2);
    let broken = Box::new(AlwaysPanic {
        inner: env::make_env("catch", 1).unwrap(),
    }) as Box<dyn Environment>;
    let broken_factory: EnvFactory = Box::new(move || {
        Ok(Box::new(AlwaysPanic {
            inner: env::make_env("catch", 1)?,
        }) as Box<dyn Environment>)
    });
    let healthy = env::make_env("catch", 2).unwrap();
    let healthy_factory: EnvFactory = Box::new(move || env::make_env("catch", 2));
    let gauges = rg.gauges.clone();
    let pool = SupervisedActors::spawn(
        vec![(broken, broken_factory), (healthy, healthy_factory)],
        rg.client.clone(),
        rg.tx.clone(),
        rg.buffers.clone(),
        rg.metrics.clone(),
        actor_cfg(&rg.spec),
        SupervisorConfig {
            max_restarts: 2,
            backoff: Duration::from_millis(1),
        },
        gauges.clone(),
    );
    // the survivor alone must keep rollouts coming
    let (got, exits) = collect_and_shutdown(rg, 3, move || pool.join());
    assert_eq!(got.len(), 3);
    assert_eq!(exits.len(), 2);
    let lost = &exits[0];
    assert_eq!(lost.actor_id(), 0);
    assert!(
        lost.panic_message().expect("typed panic exit").contains("injected"),
        "exit carries the panic message: {lost:?}"
    );
    assert!(exits[1].report().is_some(), "survivor completes normally");
    // initial fault + 2 budgeted restarts that also faulted
    assert_eq!(gauges.actor_panics.get(), 3);
    assert_eq!(gauges.actor_restarts.get(), 2);
    assert_eq!(gauges.actors_lost.get(), 1);
}

/// When the *last* live actor dies, the supervisor closes the learner
/// queue: a blocked learner-side recv returns `None` promptly instead
/// of the run hanging forever.  Pool buffers are conserved across all
/// the panics (RAII recycle during unwind).
#[test]
fn last_actor_death_closes_learner_queue_without_deadlock() {
    let rg = rig(1);
    let broken = Box::new(AlwaysPanic {
        inner: env::make_env("catch", 1).unwrap(),
    }) as Box<dyn Environment>;
    let factory: EnvFactory = Box::new(move || {
        Ok(Box::new(AlwaysPanic {
            inner: env::make_env("catch", 1)?,
        }) as Box<dyn Environment>)
    });
    let gauges = rg.gauges.clone();
    let pool = SupervisedActors::spawn(
        vec![(broken, factory)],
        rg.client.clone(),
        rg.tx.clone(),
        rg.buffers.clone(),
        rg.metrics.clone(),
        actor_cfg(&rg.spec),
        SupervisorConfig {
            max_restarts: 1,
            backoff: Duration::from_millis(1),
        },
        gauges.clone(),
    );
    // the learner side: blocks until the supervisor closes the queue
    let t0 = Instant::now();
    assert!(
        rg.rx.recv().is_none(),
        "queue must be closed once no live actors remain"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "queue closure must be prompt, not a test-timeout race"
    );
    rg.client.shutdown_for_tests();
    rg.buffers.close();
    let exits = pool.join();
    rg.infer.join().unwrap();
    assert_eq!(exits.len(), 1);
    assert!(exits[0].panic_message().is_some(), "typed panicked exit");
    assert_eq!(gauges.actor_panics.get(), 2, "initial fault + 1 restart");
    assert_eq!(gauges.actors_lost.get(), 1);
    // every rented buffer came back through the RAII guards
    assert_eq!(
        gauges.snapshot().pool_rented,
        0,
        "no rollout buffer leaked across the panics"
    );
}

// ---------------------------------------------------------------------------
// 3. watchdog + emergency checkpoint
// ---------------------------------------------------------------------------

fn tiny_manifest() -> Manifest {
    Manifest {
        dir: std::path::PathBuf::new(),
        env: "catch".into(),
        model: "stub".into(),
        obs_shape: [1, 10, 5],
        num_actions: 3,
        unroll_length: T,
        batch_size: 2,
        inference_batch: 2,
        inference_sizes: vec![2],
        param_count: 7,
        params: vec![
            LeafSpec {
                name: "conv/b".into(),
                shape: vec![3],
                dtype: DType::F32,
            },
            LeafSpec {
                name: "conv/w".into(),
                shape: vec![2, 2],
                dtype: DType::F32,
            },
        ],
        opt_state: vec![],
        stats_names: vec![],
        hyperparams: torchbeast::util::json::Json::Obj(vec![]),
        hlo_sha256: String::new(),
    }
}

fn tiny_params() -> ParamVecs {
    vec![vec![1.0, -2.0, 3.5], vec![0.0, 0.25, -0.5, 9.0]]
}

/// Wedge one stage while another beats: the watchdog's diagnosis names
/// the silent stage, the escalation closure writes an emergency
/// checkpoint and closes the pipeline queue (unblocking the
/// learner-side recv), and the checkpoint loads back intact.
#[test]
fn wedged_stage_trips_watchdog_and_writes_loadable_emergency_checkpoint() {
    let dir = std::env::temp_dir().join("tb_supervision_watchdog");
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("emergency.ckpt");
    let _ = std::fs::remove_file(&ckpt);

    let reg = HeartbeatRegistry::shared();
    let hb_actors = reg.register("actors");
    let _hb_stacker = reg.register("stacker"); // wedged: never bumped
    let gauges = PipelineGauges::shared();
    gauges.queue_depth.set(5);

    // a stand-in learner queue: escalation must unblock its consumer
    let (tx, rx) = batching_queue::<u32>(4);

    let manifest = tiny_manifest();
    let params = tiny_params();
    let stall_manifest = manifest.clone();
    let stall_params = params.clone();
    let stall_ckpt = ckpt.clone();
    let fired = Arc::new(AtomicBool::new(false));
    let fired2 = fired.clone();
    let wd = Watchdog::start(
        reg,
        gauges.clone(),
        Duration::from_millis(50),
        move |report| {
            assert_eq!(report.stage, "stacker");
            checkpoint::save_retained(&stall_ckpt, &stall_manifest, &stall_params, 9, 2)
                .expect("emergency checkpoint");
            tx.close();
            fired2.store(true, Ordering::SeqCst);
        },
    );

    // keep "actors" alive so silence is attributed to the stacker
    let stop_beating = Arc::new(AtomicBool::new(false));
    let stop2 = stop_beating.clone();
    let beater = std::thread::spawn(move || {
        while !stop2.load(Ordering::SeqCst) {
            hb_actors.inc();
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    // the blocked learner: unblocks only through the escalation path
    assert!(
        rx.recv().is_none(),
        "escalation must close the queue and unblock the learner"
    );

    // stop() joins the watchdog thread, so the escalation closure (and
    // its checkpoint write) has fully completed past this point
    let report = wd.stop().expect("hard stall recorded");
    assert!(fired.load(Ordering::SeqCst));
    stop_beating.store(true, Ordering::SeqCst);
    beater.join().unwrap();
    assert_eq!(report.stage, "stacker");
    assert!(report.silent >= Duration::from_millis(100), "{report}");
    assert!(report.diagnosis.contains("stacker"), "{report}");
    assert!(
        report.diagnosis.contains("queue 5"),
        "diagnosis carries the gauges: {report}"
    );
    assert_eq!(gauges.watchdog_stalls.get(), 1);

    // the emergency checkpoint is a complete, verified snapshot
    let (loaded, version) = checkpoint::load(&ckpt, &manifest).unwrap();
    assert_eq!(loaded, params);
    assert_eq!(version, 9);
}

// ---------------------------------------------------------------------------
// 4. corruption rejection + fallback
// ---------------------------------------------------------------------------

/// A bit-flipped weight blob is rejected with a typed error naming the
/// blob, and resume falls back to the newest intact retained
/// generation.
#[test]
fn bit_flipped_blob_is_named_and_resume_falls_back() {
    let dir = std::env::temp_dir().join("tb_supervision_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("train.ckpt");
    let _ = std::fs::remove_file(&path);
    let _ = std::fs::remove_file(checkpoint::retained_path(&path, 1));
    let _ = std::fs::remove_file(checkpoint::retained_path(&path, 2));

    let m = tiny_manifest();
    let older = tiny_params();
    let newer: ParamVecs = vec![vec![4.0, 5.0, 6.0], vec![7.0, 8.0, 9.0, 10.0]];
    checkpoint::save_retained(&path, &m, &older, 1, 2).unwrap();
    checkpoint::save_retained(&path, &m, &newer, 2, 2).unwrap();

    // flip one data bit in the newest checkpoint's last blob
    let mut bytes = std::fs::read(&path).unwrap();
    let n = bytes.len();
    bytes[n - 8 - 8 - 4] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();

    // direct load: typed rejection naming the corrupt blob
    let err = checkpoint::load(&path, &m).unwrap_err();
    match err.downcast_ref::<CheckpointError>() {
        Some(CheckpointError::CorruptBlob { leaf, .. }) => {
            assert_eq!(leaf, "conv/w", "rejection names the bad blob");
        }
        other => panic!("expected CorruptBlob, got {other:?}: {err:#}"),
    }

    // resume path: the newest intact generation wins
    let (params, version, used) = checkpoint::load_with_fallback(&path, &m).unwrap();
    assert_eq!(params, older);
    assert_eq!(version, 1);
    assert_eq!(used, checkpoint::retained_path(&path, 1));
}
