//! RPC error-path coverage: every protocol violation must surface a
//! *typed* error on both ends of the stream — an `Error` frame for the
//! peer, a loud stream error (or a terminal step) locally — and never
//! a hang.  Driven over real sockets against an in-process
//! `EnvServer`, with a raw client where the violation cannot be
//! expressed through the well-behaved `RemoteEnv` API.

use std::io::BufReader;
use std::net::TcpStream;
use std::time::Duration;

use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::env::{Environment, SlotStep, VecEnvironment};
use torchbeast::rpc::codec::{read_msg, write_frame, write_msg, Msg};
use torchbeast::rpc::{EnvServer, RemoteEnv, RemoteVecEnv};

/// Raw protocol client: connect and complete the given handshake,
/// returning (writer, reader) ready for misbehavior.
fn raw_handshake(addr: &str, hello: &Msg) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    write_msg(&mut writer, hello).unwrap();
    match read_msg(&mut reader).unwrap() {
        Msg::Spec { .. } => {}
        other => panic!("expected Spec, got {other:?}"),
    }
    // initial observation (mono) or observation batch (batched)
    match read_msg(&mut reader).unwrap() {
        Msg::Observation { .. } | Msg::ObsBatch { .. } => {}
        other => panic!("expected initial obs, got {other:?}"),
    }
    (writer, reader)
}

#[test]
fn out_of_range_action_returns_typed_error() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let (mut w, mut r) = raw_handshake(
        &addr,
        &Msg::Hello {
            env: "catch".into(),
            seed: 0,
            wrappers: WrapperCfg::default(),
        },
    );
    write_msg(&mut w, &Msg::Action { action: 99 }).unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(message.contains("out of range"), "{message}")
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
}

#[test]
fn undecodable_frame_returns_typed_error() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let (mut w, mut r) = raw_handshake(
        &addr,
        &Msg::Hello {
            env: "catch".into(),
            seed: 0,
            wrappers: WrapperCfg::default(),
        },
    );
    // tag 250 is no known message
    write_frame(&mut w, &[250u8, 1, 2, 3]).unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(message.contains("undecodable"), "{message}")
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
}

#[test]
fn batched_length_mismatch_returns_typed_error() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let (mut w, mut r) = raw_handshake(
        &addr,
        &Msg::HelloBatch {
            env: "catch".into(),
            seeds: vec![1, 2, 3, 4],
            wrappers: WrapperCfg::default(),
        },
    );
    // 3 actions for a group of 4
    write_msg(
        &mut w,
        &Msg::ActionBatch {
            actions: vec![0, 1, 2],
        },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(message.contains("action batch of 3"), "{message}")
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
}

#[test]
fn batched_out_of_range_action_names_the_slot() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let (mut w, mut r) = raw_handshake(
        &addr,
        &Msg::HelloBatch {
            env: "catch".into(),
            seeds: vec![1, 2],
            wrappers: WrapperCfg::default(),
        },
    );
    write_msg(
        &mut w,
        &Msg::ActionBatch {
            actions: vec![0, 77],
        },
    )
    .unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(
                message.contains("slot 1") && message.contains("out of range"),
                "{message}"
            );
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
}

/// A group whose ObsBatch frames could never fit under MAX_FRAME is
/// rejected with a typed Error at handshake time, not an opaque EOF
/// on the first oversized write.
#[test]
fn oversized_group_rejected_at_handshake() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let stream = TcpStream::connect(addr.as_str()).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // 1.2M slots x >= 17 bytes per slot per ObsBatch blows the 16 MiB
    // cap for any env; the HelloBatch itself (~9.6 MB) still fits
    write_msg(
        &mut writer,
        &Msg::HelloBatch {
            env: "catch".into(),
            seeds: vec![0; 1_200_000],
            wrappers: WrapperCfg::default(),
        },
    )
    .unwrap();
    match read_msg(&mut reader).unwrap() {
        Msg::Error { message } => {
            assert!(message.contains("use smaller groups"), "{message}")
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
}

/// A client that vanishes mid-episode must not wedge the server: the
/// stream thread exits with a loud error and the server keeps
/// accepting and serving new streams.
#[test]
fn mid_episode_disconnect_leaves_server_serving() {
    let mut server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    {
        let mut env = RemoteEnv::connect(&addr, "catch", 0, &WrapperCfg::default()).unwrap();
        let mut obs = vec![0.0; env.spec().obs_len()];
        env.reset(&mut obs);
        env.step(1, &mut obs); // mid-episode
        // drop without Bye: simulate an abrupt death by leaking the
        // socket state through a raw shutdown instead of close()
    } // RemoteEnv's Drop sends Bye; kill a raw one too:
    {
        let stream = TcpStream::connect(&addr).unwrap();
        let mut writer = stream.try_clone().unwrap();
        write_msg(
            &mut writer,
            &Msg::Hello {
                env: "catch".into(),
                seed: 1,
                wrappers: WrapperCfg::default(),
            },
        )
        .unwrap();
        // vanish mid-handshake-reply (no Bye, no reads)
        drop(writer);
        drop(stream);
    }
    // the server still serves fresh streams end to end
    let mut env = RemoteEnv::connect(&addr, "catch", 2, &WrapperCfg::default()).unwrap();
    let mut obs = vec![0.0; env.spec().obs_len()];
    env.reset(&mut obs);
    for i in 0..30 {
        if env.step(i % 3, &mut obs).done {
            env.reset(&mut obs);
        }
    }
    assert!(
        server
            .steps_served
            .load(std::sync::atomic::Ordering::Relaxed)
            >= 31
    );
    server.shutdown(); // must not hang on the dead streams
}

/// Server death mid-episode surfaces client-side as a terminal step
/// (mono) / all-terminal steps + a recorded typed cause (batched) —
/// the actor keeps running instead of hanging.
#[test]
fn server_death_surfaces_as_terminal_steps() {
    let mut server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut env = RemoteEnv::connect(&addr, "catch", 0, &WrapperCfg::default()).unwrap();
    let seeds = [0u64, 1];
    let mut venv = RemoteVecEnv::connect(&addr, "catch", &seeds, &WrapperCfg::default()).unwrap();
    let mut obs = vec![0.0; env.spec().obs_len()];
    let mut block = vec![0.0; venv.batch() * venv.spec().obs_len()];
    let mut steps = vec![SlotStep::default(); venv.batch()];
    env.reset(&mut obs);
    venv.reset_all(&mut block);
    env.step(1, &mut obs);
    venv.step_batch(&[1, 1], &mut block, &mut steps);
    assert!(venv.last_error().is_none());

    server.shutdown();

    let st = env.step(1, &mut obs);
    assert!(st.done, "transport loss must read as a terminal step");
    assert_eq!(st.reward, 0.0);
    venv.step_batch(&[1, 1], &mut block, &mut steps);
    assert!(steps.iter().all(|s| s.done && s.reward == 0.0));
    assert!(
        venv.last_error().is_some(),
        "the typed cause must be recorded client-side"
    );
    // subsequent steps stay terminal (cached obs replayed), no panic
    venv.step_batch(&[0, 2], &mut block, &mut steps);
    assert!(steps.iter().all(|s| s.done));
}

/// The reconnect/retry rung (ROADMAP): a batched stream the server
/// kills mid-run recovers through `--env_reconnect_attempts` bounded
/// reconnects — a fresh `HelloBatch` handshake whose episode-start
/// frames surface as the failed round's all-terminal observations —
/// instead of latching the whole group terminal.  The kill mechanism
/// here is a server-side stream drop (the server answers a protocol
/// violation with a typed Error and abandons the stream), which is a
/// mid-run stream death from the group's point of view; the server
/// itself stays up to accept the reconnect.
#[test]
fn vec_stream_reconnects_after_server_kills_it() {
    use torchbeast::telemetry::gauges::PipelineGauges;

    let g = PipelineGauges::shared();
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let seeds = [3u64, 4];
    let mut venv = RemoteVecEnv::connect(&addr, "catch", &seeds, &WrapperCfg::default()).unwrap();
    venv.set_reconnect(2);
    venv.set_gauges(g.clone());
    let b = venv.batch();
    let obs_len = venv.spec().obs_len();
    let mut block = vec![0.0f32; b * obs_len];
    let mut steps = vec![SlotStep::default(); b];
    venv.reset_all(&mut block);
    venv.step_batch(&[1, 1], &mut block, &mut steps);
    assert!(venv.last_error().is_none());
    assert!(!venv.last_step_synthesized());
    assert_eq!(venv.reconnects(), 0);

    // kill #1: the server rejects the out-of-range action with a
    // typed Error and drops the stream -> the client reconnects
    venv.step_batch(&[9, 1], &mut block, &mut steps);
    assert!(
        steps.iter().all(|s| s.done && s.reward == 0.0),
        "the failed round must read as all-terminal steps"
    );
    assert!(
        venv.last_error().is_none(),
        "a successful reconnect must not latch the stream"
    );
    assert!(
        venv.last_step_synthesized(),
        "the papered-over round must be flagged fabricated (kept out of metrics)"
    );
    assert_eq!(venv.reconnects(), 1);
    assert_eq!(g.env_reconnects.get(), 1, "reconnects are counted in gauges");
    // the returned observations are the fresh streams' episode-start
    // frames: catch frames light exactly two pixels per slot
    for s in 0..b {
        let row = &block[s * obs_len..(s + 1) * obs_len];
        assert_eq!(
            row.iter().filter(|&&v| v == 1.0).count(),
            2,
            "slot {s} must show a fresh episode-start frame"
        );
    }

    // the reconnected stream is live: whole episodes play out again,
    // and real rounds are no longer flagged as synthesized
    let mut dones = 0;
    for i in 0..30 {
        venv.step_batch(&[i % 3, (i + 1) % 3], &mut block, &mut steps);
        assert!(!venv.last_step_synthesized(), "round {i} is real");
        dones += steps.iter().filter(|s| s.done).count();
    }
    assert!(venv.last_error().is_none());
    assert!(dones > 0, "episodes must complete on the reconnected stream");

    // kill #2 consumes the last budgeted attempt; kill #3 latches
    venv.step_batch(&[9, 1], &mut block, &mut steps);
    assert!(venv.last_error().is_none());
    assert_eq!(venv.reconnects(), 2);
    venv.step_batch(&[9, 1], &mut block, &mut steps);
    let err = venv.last_error().expect("budget exhausted must latch");
    assert!(err.contains("out of range"), "{err}");
    // latched: later steps synthesize terminals without reconnecting
    venv.step_batch(&[1, 1], &mut block, &mut steps);
    assert!(steps.iter().all(|s| s.done));
    assert_eq!(venv.reconnects(), 2);
    assert_eq!(g.env_reconnects.get(), 2);
}

/// With no server listening, every budgeted reconnect attempt fails
/// fast and the group latches terminal exactly like the classic path.
#[test]
fn vec_stream_latches_when_reconnects_cannot_land() {
    let mut server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let mut venv =
        RemoteVecEnv::connect(&addr, "catch", &[0, 1], &WrapperCfg::default()).unwrap();
    venv.set_reconnect(3);
    let b = venv.batch();
    let mut block = vec![0.0f32; b * venv.spec().obs_len()];
    let mut steps = vec![SlotStep::default(); b];
    venv.reset_all(&mut block);
    venv.step_batch(&[1, 1], &mut block, &mut steps);
    assert!(venv.last_error().is_none());

    server.shutdown(); // nothing left to reconnect to

    venv.step_batch(&[1, 1], &mut block, &mut steps);
    assert!(steps.iter().all(|s| s.done && s.reward == 0.0));
    assert!(
        venv.last_error().is_some(),
        "exhausted reconnects must latch the typed cause"
    );
    assert_eq!(venv.reconnects(), 0, "no reconnect could land");
    // latched streams never touch the socket (or the budget) again
    venv.step_batch(&[0, 2], &mut block, &mut steps);
    assert!(steps.iter().all(|s| s.done));
}

/// The `--server_cpus` rung (ROADMAP): with the serve-loop thread cap
/// at 1, a second stream's handshake parks in the TCP backlog until
/// the first stream closes — deferred, never errored.
#[test]
fn stream_cap_defers_streams_beyond_server_cpus() {
    use torchbeast::telemetry::gauges::PipelineGauges;

    let mut server =
        EnvServer::start_with_options("127.0.0.1:0", PipelineGauges::shared(), 1).unwrap();
    let addr = server.addr.to_string();
    let env1 = RemoteEnv::connect(&addr, "catch", 0, &WrapperCfg::default()).unwrap();
    let addr2 = addr.clone();
    let second = std::thread::spawn(move || {
        let mut env2 = RemoteEnv::connect(&addr2, "catch", 1, &WrapperCfg::default()).unwrap();
        let mut obs = vec![0.0; env2.spec().obs_len()];
        env2.reset(&mut obs);
        let mut n = 0;
        for i in 0..20 {
            if env2.step(i % 3, &mut obs).done {
                env2.reset(&mut obs);
            }
            n += 1;
        }
        n
    });
    std::thread::sleep(Duration::from_millis(100));
    assert!(
        !second.is_finished(),
        "the second stream must wait for a serving slot"
    );
    drop(env1); // Bye: the serving thread retires, freeing the slot
    assert_eq!(second.join().unwrap(), 20);
    server.shutdown();
}

/// RemoteVecEnv receiving a typed server rejection (here: an action
/// the server's spec rejects) records the server's message and turns
/// all-terminal instead of hanging on a stream the server abandoned.
#[test]
fn remote_vec_surfaces_server_rejection() {
    let server = EnvServer::start("127.0.0.1:0").unwrap();
    let addr = server.addr.to_string();
    let seeds = [5u64, 6, 7];
    let mut venv = RemoteVecEnv::connect(&addr, "catch", &seeds, &WrapperCfg::default()).unwrap();
    let b = venv.batch();
    let mut block = vec![0.0; b * venv.spec().obs_len()];
    let mut steps = vec![SlotStep::default(); b];
    venv.reset_all(&mut block);
    // catch has 3 actions; 9 is out of range — the server answers with
    // an Error frame and drops the stream
    venv.step_batch(&[9, 0, 0], &mut block, &mut steps);
    assert!(steps.iter().all(|s| s.done));
    let err = venv.last_error().expect("typed cause recorded");
    assert!(err.contains("out of range"), "{err}");
}
