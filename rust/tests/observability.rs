//! Observability integration: the tracer, the gauge-CSV stage columns
//! and the /metrics exposition endpoint exercised against live
//! pipelines (DESIGN.md §Tracing).
//!
//! The exporter test runs artifact-free against a real policy-serving
//! stack; the trainer tests need `make artifacts` (skipped loudly
//! otherwise, like `train_integration.rs`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::path::{Path, PathBuf};

use torchbeast::config::TrainConfig;
use torchbeast::coordinator;
use torchbeast::serving::{run_inference_loop, PolicyClient, PolicyServer, PolicyServerConfig};
use torchbeast::telemetry::exporter::MetricsServer;
use torchbeast::telemetry::gauges::PipelineGauges;
use torchbeast::telemetry::sampler::{GAUGE_CURVE_HEADER, GAUGE_CURVE_SCHEMA_VERSION};
use torchbeast::util::json::Json;

fn artifact_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/catch");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/catch missing (run `make artifacts`)");
        None
    }
}

fn base_cfg(dir: PathBuf) -> TrainConfig {
    TrainConfig {
        artifact_dir: dir,
        num_actors: 4,
        total_steps: 8,
        seed: 3,
        log_interval: 0,
        ..TrainConfig::default()
    }
}

fn scrape(addr: std::net::SocketAddr) -> (String, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream
        .write_all(b"GET /metrics HTTP/1.0\r\nHost: test\r\n\r\n")
        .unwrap();
    let mut text = String::new();
    stream.read_to_string(&mut text).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    (head.to_string(), body.to_string())
}

/// Pull one sample value out of a Prometheus text body by its exact
/// name-plus-labels prefix.
fn sample_value(body: &str, series: &str) -> u64 {
    body.lines()
        .find_map(|l| l.strip_prefix(series))
        .unwrap_or_else(|| panic!("series {series:?} missing from:\n{body}"))
        .trim()
        .parse()
        .expect("numeric sample")
}

/// Artifact-free end-to-end scrape: a live policy-serving stack and a
/// metrics endpoint share one gauge registry; after real served
/// rounds, `GET /metrics` must reflect both the serving counters and
/// the `serve_round` stage histogram the spans recorded.
#[test]
fn metrics_endpoint_reflects_live_policy_serving() {
    let gauges = PipelineGauges::shared();
    let cfg = PolicyServerConfig::new([1, 2, 2], 3, 4);
    let mut server =
        PolicyServer::start_with_gauges("127.0.0.1:0", cfg, gauges.clone()).unwrap();
    let stream = server.take_batch_stream().unwrap();
    let backend = std::thread::spawn(move || {
        run_inference_loop(&stream, 3, |_obs, n, logits, baselines| {
            logits.clear();
            baselines.clear();
            logits.resize(n * 3, 0.5);
            baselines.resize(n, 0.0);
            Ok(())
        })
        .unwrap();
    });
    let metrics = MetricsServer::start("127.0.0.1:0", gauges.clone()).unwrap();

    let addr = server.addr.to_string();
    let mut client = PolicyClient::connect(&[addr], &[7, 8]).unwrap();
    let mut actions = [0usize; 2];
    for round in 0..5 {
        let obs: Vec<f32> = (0..8).map(|i| (round * 8 + i) as f32 * 0.01).collect();
        client.act(&obs, &mut actions).unwrap();
    }

    let (head, body) = scrape(metrics.local_addr());
    assert!(head.starts_with("HTTP/1.0 200 OK"), "{head}");
    assert_eq!(sample_value(&body, "tb_serve_requests_total "), 5);
    assert_eq!(sample_value(&body, "tb_serve_busy_total "), 0);
    assert!(
        sample_value(&body, "tb_serve_latency_p99_us ") > 0,
        "latency ring must have recorded the rounds:\n{body}"
    );
    // every served round ran inside a serve_round span; the histogram
    // is process-global, so other tests can only add to the count
    assert!(
        sample_value(&body, "tb_stage_duration_us_count{stage=\"serve_round\"} ") >= 5,
        "serve_round spans missing from the stage histogram:\n{body}"
    );
    assert!(
        body.contains("tb_stage_duration_us_bucket{stage=\"serve_round\",le=\"+Inf\"}"),
        "{body}"
    );

    drop(client);
    server.shutdown();
    backend.join().unwrap();
    assert!(metrics.shutdown() >= 1, "at least our scrape was answered");
}

/// `--trace_path` through the real driver: the committed file is a
/// loadable Chrome `trace_event` JSON array whose `"X"` events carry
/// the pipeline stage names, with actor and learner work both present.
#[test]
fn train_with_trace_path_writes_loadable_chrome_trace() {
    let Some(dir) = artifact_dir() else { return };
    let tmp = std::env::temp_dir().join("tb_observability_trace");
    std::fs::create_dir_all(&tmp).unwrap();
    let trace = tmp.join("trace.json");
    let _ = std::fs::remove_file(&trace);

    let mut cfg = base_cfg(dir);
    cfg.trace_path = Some(trace.clone());
    cfg.gauge_sample_ms = 20;
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 8);

    let text = std::fs::read_to_string(&trace).expect("trace file committed");
    let root = Json::parse(&text).expect("trace must be valid JSON");
    let events = root.as_arr().expect("top level is the event array");
    assert!(!events.is_empty(), "a live run must emit spans");
    let known: Vec<&str> = torchbeast::telemetry::trace::STAGES
        .iter()
        .map(|s| s.name())
        .collect();
    let mut seen_stages: Vec<&str> = Vec::new();
    for ev in events {
        let ph = ev.get("ph").and_then(|p| p.as_str()).expect("ph field");
        assert!(ph == "X" || ph == "M", "unexpected phase {ph:?}");
        assert!(ev.get("pid").is_some() && ev.get("tid").is_some());
        if ph == "X" {
            assert!(ev.get("ts").is_some() && ev.get("dur").is_some());
            let name = ev.get("name").and_then(|n| n.as_str()).expect("name");
            assert!(known.contains(&name), "unknown stage {name:?}");
            if !seen_stages.contains(&name) {
                seen_stages.push(name);
            }
        }
    }
    for must in ["actor_unroll", "env_step", "learner_step", "weight_publish"] {
        assert!(
            seen_stages.contains(&must),
            "stage {must} missing from the trace (saw {seen_stages:?})"
        );
    }
}

/// `--metrics_addr` through the real driver: the endpoint starts with
/// the run and shuts down cleanly with it (port 0 keeps the test
/// parallel-safe; the exporter's scrape contract is pinned above and
/// in the exporter's own suite).
#[test]
fn train_with_metrics_addr_starts_and_stops_cleanly() {
    let Some(dir) = artifact_dir() else { return };
    let mut cfg = base_cfg(dir);
    cfg.total_steps = 4;
    cfg.metrics_addr = Some("127.0.0.1:0".to_string());
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 4);
}

/// The gauge CSV after the v2 schema bump: every row leads with the
/// schema version, matches the header arity, and the per-stage
/// duration columns carry real span quantiles from the run.
#[test]
fn gauge_csv_v2_carries_stage_duration_columns() {
    let Some(dir) = artifact_dir() else { return };
    let tmp = std::env::temp_dir().join("tb_observability_csv");
    std::fs::create_dir_all(&tmp).unwrap();
    let csv = tmp.join("gauges.csv");
    let _ = std::fs::remove_file(&csv);

    let mut cfg = base_cfg(dir);
    cfg.gauge_log_path = Some(csv.clone());
    cfg.gauge_sample_ms = 10;
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 8);

    let text = std::fs::read_to_string(&csv).unwrap();
    let mut lines = text.lines();
    let header = lines.next().expect("header row");
    assert_eq!(header, GAUGE_CURVE_HEADER);
    let cols = header.split(',').count();
    let version_col = GAUGE_CURVE_SCHEMA_VERSION.to_string();
    let p50_col = header
        .split(',')
        .position(|c| c == "learner_step_p50_us")
        .expect("stage column in header");
    let mut rows = 0usize;
    let mut learner_p50_seen = false;
    for row in lines {
        rows += 1;
        let fields: Vec<&str> = row.split(',').collect();
        assert_eq!(fields.len(), cols, "row arity matches header: {row}");
        assert_eq!(fields[0], version_col, "schema version leads every row");
        let p50: u64 = fields[p50_col].parse().expect("numeric stage column");
        if p50 > 0 {
            learner_p50_seen = true;
        }
    }
    assert!(rows >= 1, "the 10ms sampler must land rows in an 8-step run");
    assert!(
        learner_p50_seen,
        "learner_step p50 stays zero despite 8 real learner steps:\n{text}"
    );
}
