//! Fault-injection suite for the policy-serving tier (DESIGN.md
//! §Policy-Server): replica death mid-stream must fail over, a
//! saturated slot pool must answer typed `Busy` frames (never deadlock,
//! never queue unboundedly), protocol violations must surface typed
//! `Error` frames on both ends, and — the determinism contract — a
//! fixed checkpoint + fixed seeds must yield bit-identical actions
//! whether observations are served over TCP through `policy-server` or
//! submitted to an in-process batcher.
//!
//! Everything runs on stub linear policies through
//! `serving::run_inference_loop`, so the suite needs no AOT artifacts
//! (mirrors the alloc-regression/throughput-bench approach).

use std::io::BufReader;
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use torchbeast::agent::sample_action_scratch;
use torchbeast::coordinator::dynamic_batcher::{dynamic_batcher, BatcherConfig};
use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::rpc::codec::{read_msg, write_frame, write_msg, Msg, ObsHeader};
use torchbeast::runtime::checkpoint;
use torchbeast::runtime::manifest::DType;
use torchbeast::runtime::{LeafSpec, Manifest, ParamVecs};
use torchbeast::serving::{run_inference_loop, PolicyClient, PolicyServer, PolicyServerConfig};
use torchbeast::telemetry::gauges::PipelineGauges;
use torchbeast::util::json::Json;
use torchbeast::util::rng::Rng;

const OBS_SHAPE: [usize; 3] = [1, 2, 3];
const OBS_LEN: usize = 6;
const NUM_ACTIONS: usize = 4;

/// Deterministic linear policy weights: `logits = W · obs + b`.
fn stub_params() -> ParamVecs {
    let mut w = vec![0.0f32; NUM_ACTIONS * OBS_LEN];
    for (k, v) in w.iter_mut().enumerate() {
        let (a, i) = (k / OBS_LEN, k % OBS_LEN);
        *v = ((a * 31 + i * 7) % 13) as f32 * 0.17 - 0.6;
    }
    let b = (0..NUM_ACTIONS).map(|a| a as f32 * 0.05 - 0.1).collect();
    vec![w, b]
}

/// A manifest matching `stub_params` so the weights can round-trip
/// through the TBCK checkpoint format (the "fixed checkpoint" half of
/// the determinism contract).
fn stub_manifest() -> Manifest {
    Manifest {
        dir: PathBuf::new(),
        env: "catch".into(),
        model: "linear-stub".into(),
        obs_shape: OBS_SHAPE,
        num_actions: NUM_ACTIONS,
        unroll_length: 4,
        batch_size: 2,
        inference_batch: 4,
        inference_sizes: vec![4],
        param_count: NUM_ACTIONS * OBS_LEN + NUM_ACTIONS,
        params: vec![
            LeafSpec {
                name: "linear/w".into(),
                shape: vec![NUM_ACTIONS, OBS_LEN],
                dtype: DType::F32,
            },
            LeafSpec {
                name: "linear/b".into(),
                shape: vec![NUM_ACTIONS],
                dtype: DType::F32,
            },
        ],
        opt_state: vec![],
        stats_names: vec![],
        hyperparams: Json::Obj(vec![]),
        hlo_sha256: String::new(),
    }
}

/// The stub forward pass, shared by the served and in-process paths
/// (identical arithmetic order, so results are bit-comparable).
fn linear_forward(
    params: &ParamVecs,
    obs: &[f32],
    n: usize,
    logits: &mut Vec<f32>,
    baselines: &mut Vec<f32>,
) {
    let (w, b) = (&params[0], &params[1]);
    logits.clear();
    baselines.clear();
    for k in 0..n {
        let row = &obs[k * OBS_LEN..(k + 1) * OBS_LEN];
        for a in 0..NUM_ACTIONS {
            let mut acc = b[a];
            for (i, x) in row.iter().enumerate() {
                acc += w[a * OBS_LEN + i] * x;
            }
            logits.push(acc);
        }
        baselines.push(0.0);
    }
}

/// Start a policy server backed by the stub policy.  `delay` emulates
/// inference cost (holds slots checked out, for saturation tests);
/// `gate` (when given) blocks the backend before its first response
/// until the flag goes true, setting `started` on batch pickup — the
/// deterministic way to pin the pool saturated.
#[allow(clippy::type_complexity)]
fn start_stub_server(
    cfg: PolicyServerConfig,
    params: ParamVecs,
    gauges: Arc<PipelineGauges>,
    delay: Duration,
    gate: Option<(Arc<AtomicBool>, Arc<AtomicBool>)>,
) -> (PolicyServer, JoinHandle<()>) {
    let mut server = PolicyServer::start_with_gauges("127.0.0.1:0", cfg, gauges).unwrap();
    let stream = server.take_batch_stream().unwrap();
    let backend = std::thread::spawn(move || {
        run_inference_loop(&stream, NUM_ACTIONS, move |obs, n, logits, baselines| {
            if let Some((started, open)) = &gate {
                started.store(true, Ordering::SeqCst); // test-only handshake flag
                while !open.load(Ordering::SeqCst) {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
            if !delay.is_zero() {
                std::thread::sleep(delay);
            }
            linear_forward(&params, obs, n, logits, baselines);
            Ok(())
        })
        .unwrap();
    });
    (server, backend)
}

/// Raw policy-protocol client: HelloBatch → Spec handshake, returning
/// (writer, reader) ready for misbehavior (or manual rounds, where
/// `Busy` frames are observable — `PolicyClient` absorbs them).
fn raw_policy_handshake(addr: &str, seeds: &[u64]) -> (TcpStream, BufReader<TcpStream>) {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_nodelay(true).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    write_msg(
        &mut writer,
        &Msg::HelloBatch {
            env: "policy".into(),
            seeds: seeds.to_vec(),
            wrappers: WrapperCfg::default(),
        },
    )
    .unwrap();
    match read_msg(&mut reader).unwrap() {
        Msg::Spec {
            channels,
            height,
            width,
            num_actions,
        } => {
            assert_eq!(
                (channels as usize * height as usize * width as usize),
                OBS_LEN
            );
            assert_eq!(num_actions as usize, NUM_ACTIONS);
        }
        other => panic!("expected Spec, got {other:?}"),
    }
    (writer, reader)
}

fn obs_batch_msg(b: usize, fill: f32) -> Msg {
    Msg::ObsBatch {
        headers: vec![ObsHeader::default(); b],
        obs: vec![fill; b * OBS_LEN],
    }
}

// ---------------------------------------------------------------------------
// Determinism: served == in-process, for a fixed checkpoint and seeds
// ---------------------------------------------------------------------------

#[test]
fn served_actions_match_in_process_batcher() {
    // the "fixed checkpoint": round-trip the stub weights through the
    // TBCK format and serve what `load` returns
    let manifest = stub_manifest();
    let dir = std::env::temp_dir().join("tb_policy_server_test");
    let path = dir.join("det.ckpt");
    checkpoint::save(&path, &manifest, &stub_params(), 3).unwrap();
    let (params, version) = checkpoint::load(&path, &manifest).unwrap();
    assert_eq!(version, 3, "weight version survives the round trip");
    assert_eq!(params, stub_params());

    const B: usize = 4;
    const ROUNDS: usize = 40;
    let seeds: Vec<u64> = (0..B as u64).map(|s| 1000 + s).collect();
    // deterministic observation stream, same for both paths
    let mut obs_rng = Rng::new(7);
    let obs_stream: Vec<Vec<f32>> = (0..ROUNDS)
        .map(|_| {
            (0..B * OBS_LEN)
                .map(|_| obs_rng.next_f32() * 3.0 - 1.5)
                .collect()
        })
        .collect();

    // path 1: served over TCP through the policy server
    let served: Vec<Vec<usize>> = {
        let cfg = PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, B)
            .with_batch_timeout(Duration::from_micros(200));
        let (mut server, backend) = start_stub_server(
            cfg,
            params.clone(),
            PipelineGauges::shared(),
            Duration::ZERO,
            None,
        );
        let addr = server.addr.to_string();
        let mut client = PolicyClient::connect(&[addr], &seeds).unwrap();
        let mut actions = [0usize; B];
        let out = obs_stream
            .iter()
            .map(|obs| {
                client.act(obs, &mut actions).unwrap();
                actions.to_vec()
            })
            .collect();
        drop(client);
        server.shutdown();
        backend.join().unwrap();
        out
    };

    // path 2: the same obs through an in-process batcher, sampling
    // with the same per-slot seeds exactly as serve_round does
    let in_process: Vec<Vec<usize>> = {
        let bcfg = BatcherConfig::new(B, Duration::from_micros(200), OBS_LEN, NUM_ACTIONS);
        let (client, stream) = dynamic_batcher(bcfg);
        let params2 = params.clone();
        let backend = std::thread::spawn(move || {
            run_inference_loop(&stream, NUM_ACTIONS, move |obs, n, logits, baselines| {
                linear_forward(&params2, obs, n, logits, baselines);
                Ok(())
            })
            .unwrap();
        });
        let mut submitter = client.slice_submitter();
        let mut rngs: Vec<Rng> = seeds.iter().map(|&s| Rng::new(s)).collect();
        let mut scratch = vec![0.0f32; NUM_ACTIONS];
        let mut logits = vec![0.0f32; B * NUM_ACTIONS];
        let mut baselines = vec![0.0f32; B];
        let out = obs_stream
            .iter()
            .map(|obs| {
                submitter
                    .submit_slice(obs, &mut logits, &mut baselines)
                    .unwrap();
                rngs.iter_mut()
                    .enumerate()
                    .map(|(s, rng)| {
                        let row = &logits[s * NUM_ACTIONS..(s + 1) * NUM_ACTIONS];
                        sample_action_scratch(row, &mut scratch, rng)
                    })
                    .collect()
            })
            .collect();
        client.close();
        backend.join().unwrap();
        out
    };

    assert_eq!(
        served, in_process,
        "served actions must be bit-identical to the in-process batcher's"
    );
}

// ---------------------------------------------------------------------------
// Failover: kill a replica mid-stream, the client resumes on the survivor
// ---------------------------------------------------------------------------

#[test]
fn client_fails_over_to_surviving_replica_mid_stream() {
    let mk = || {
        start_stub_server(
            PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, 4)
                .with_batch_timeout(Duration::from_micros(200)),
            stub_params(),
            PipelineGauges::shared(),
            Duration::ZERO,
            None,
        )
    };
    let (mut server_a, backend_a) = mk();
    let (mut server_b, backend_b) = mk();
    let addrs = vec![server_a.addr.to_string(), server_b.addr.to_string()];

    let seeds = [5u64, 6];
    let mut client = PolicyClient::connect(&addrs, &seeds).unwrap();
    client.set_reconnect(4);
    assert_eq!(client.replica(), 0, "connects to the first reachable replica");

    let obs = vec![0.25f32; 2 * OBS_LEN];
    let mut actions = [0usize; 2];
    for _ in 0..5 {
        client.act(&obs, &mut actions).unwrap();
    }
    assert_eq!(server_a.requests_served.load(Ordering::Relaxed), 5);

    // kill replica A mid-stream; the next rounds must transparently
    // resume on B (fresh handshake, same seeds)
    server_a.shutdown();
    backend_a.join().unwrap();
    for _ in 0..5 {
        client.act(&obs, &mut actions).unwrap();
        assert!(actions.iter().all(|&a| a < NUM_ACTIONS), "{actions:?}");
    }
    assert!(client.reconnects() >= 1, "failover must be recorded");
    assert_eq!(client.replica(), 1, "the survivor serves the stream");
    assert!(client.last_error().is_none(), "client is healthy, not latched");
    drop(client);
    server_b.shutdown();
    backend_b.join().unwrap();
    assert_eq!(server_b.requests_served.load(Ordering::Relaxed), 5);
}

#[test]
fn exhausted_reconnect_budget_latches_the_client() {
    let (mut server, backend) = start_stub_server(
        PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, 4)
            .with_batch_timeout(Duration::from_micros(200)),
        stub_params(),
        PipelineGauges::shared(),
        Duration::ZERO,
        None,
    );
    let addrs = vec![server.addr.to_string()];
    let seeds = [1u64];
    let mut client = PolicyClient::connect(&addrs, &seeds).unwrap();
    client.set_reconnect(2);

    let obs = vec![0.5f32; OBS_LEN];
    let mut actions = [0usize; 1];
    client.act(&obs, &mut actions).unwrap();

    // no replica left: the budget drains against a dead address
    server.shutdown();
    backend.join().unwrap();
    let err = client.act(&obs, &mut actions).unwrap_err().to_string();
    assert!(
        err.contains("reconnect budget exhausted"),
        "budget exhaustion must be the typed cause: {err}"
    );
    // latched: later calls fail immediately without touching a socket
    let err = client.act(&obs, &mut actions).unwrap_err().to_string();
    assert!(err.contains("latched"), "{err}");
    assert!(client.last_error().is_some());
}

// ---------------------------------------------------------------------------
// Admission control: saturation answers typed Busy, never deadlocks
// ---------------------------------------------------------------------------

/// Deterministic saturation: a gated backend pins one full batch (the
/// whole 2-slot pool) checked out, so the next stream's submission
/// *must* exhaust its admission wait and draw a typed `Busy`.
#[test]
fn saturated_pool_answers_typed_busy() {
    let started = Arc::new(AtomicBool::new(false));
    let open = Arc::new(AtomicBool::new(false));
    let gauges = PipelineGauges::shared();
    let (mut server, backend) = start_stub_server(
        PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, 2)
            .with_slots(2)
            .with_admission(Duration::from_millis(2))
            .with_retry_after_ms(3)
            .with_batch_timeout(Duration::from_micros(100)),
        stub_params(),
        gauges.clone(),
        Duration::ZERO,
        Some((started.clone(), open.clone())),
    );
    let addr = server.addr.to_string();

    // stream A checks out the whole pool; its batch blocks on the gate
    let (mut wa, mut ra) = raw_policy_handshake(&addr, &[10, 11]);
    write_msg(&mut wa, &obs_batch_msg(2, 0.1)).unwrap();
    while !started.load(Ordering::SeqCst) {
        std::thread::sleep(Duration::from_millis(1));
    }

    // stream B now cannot be admitted within the bound: typed Busy,
    // stream stays open
    let (mut wb, mut rb) = raw_policy_handshake(&addr, &[12]);
    write_msg(&mut wb, &obs_batch_msg(1, 0.2)).unwrap();
    match read_msg(&mut rb).unwrap() {
        Msg::Busy { retry_after_ms } => assert_eq!(retry_after_ms, 3),
        other => panic!("expected Busy, got {other:?}"),
    }

    // open the gate: A's batch completes, the pool frees, B's retry on
    // the SAME stream is served
    open.store(true, Ordering::SeqCst);
    match read_msg(&mut ra).unwrap() {
        Msg::ActionBatch { actions } => assert_eq!(actions.len(), 2),
        other => panic!("expected ActionBatch, got {other:?}"),
    }
    write_msg(&mut wb, &obs_batch_msg(1, 0.2)).unwrap();
    match read_msg(&mut rb).unwrap() {
        Msg::ActionBatch { actions } => assert_eq!(actions.len(), 1),
        other => panic!("expected ActionBatch after retry, got {other:?}"),
    }

    write_msg(&mut wa, &Msg::Bye).unwrap();
    write_msg(&mut wb, &Msg::Bye).unwrap();
    server.shutdown();
    backend.join().unwrap();
    let snap = gauges.snapshot();
    assert_eq!(snap.serve_busy, 1, "exactly one Busy rejection");
    assert_eq!(snap.serve_requests, 2, "both streams eventually served");
    assert!(
        snap.to_string().contains("served 2 (busy 1)"),
        "report line carries the serving section: {snap}"
    );
}

/// Stress: more single-slot streams than slots, all pounding a slow
/// backend.  Every stream must finish its quota (Busy rounds retried),
/// every thread must join — saturation can reject, never deadlock.
#[test]
fn oversubscribed_streams_all_complete_without_deadlock() {
    const STREAMS: usize = 6;
    const QUOTA: u64 = 15;
    let gauges = PipelineGauges::shared();
    let (mut server, backend) = start_stub_server(
        PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, 2)
            .with_slots(2)
            .with_admission(Duration::from_millis(1))
            .with_retry_after_ms(1)
            .with_batch_timeout(Duration::from_micros(100)),
        stub_params(),
        gauges.clone(),
        Duration::from_millis(3),
        None,
    );
    let addr = server.addr.to_string();
    let busy_seen = Arc::new(AtomicU64::new(0));

    let clients: Vec<JoinHandle<()>> = (0..STREAMS)
        .map(|t| {
            let addr = addr.clone();
            let busy_seen = busy_seen.clone();
            std::thread::spawn(move || {
                let (mut w, mut r) = raw_policy_handshake(&addr, &[t as u64]);
                let msg = obs_batch_msg(1, t as f32 * 0.01);
                let mut served = 0u64;
                let mut rounds = 0u64;
                while served < QUOTA {
                    write_msg(&mut w, &msg).unwrap();
                    match read_msg(&mut r).unwrap() {
                        Msg::ActionBatch { actions } => {
                            assert_eq!(actions.len(), 1);
                            served += 1;
                        }
                        Msg::Busy { retry_after_ms } => {
                            busy_seen.fetch_add(1, Ordering::Relaxed);
                            std::thread::sleep(Duration::from_millis(retry_after_ms as u64));
                        }
                        other => panic!("expected ActionBatch/Busy, got {other:?}"),
                    }
                    rounds += 1;
                    assert!(rounds < 100_000, "stream {t} livelocked");
                }
                write_msg(&mut w, &Msg::Bye).unwrap();
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap(); // a deadlock shows up here as a hang/panic
    }
    server.shutdown();
    backend.join().unwrap();

    let snap = gauges.snapshot();
    assert_eq!(
        snap.serve_requests,
        STREAMS as u64 * QUOTA,
        "every stream finished its quota"
    );
    assert_eq!(
        snap.serve_busy,
        busy_seen.load(Ordering::Relaxed),
        "server-side Busy count matches the frames clients saw"
    );
    assert!(
        snap.serve_p99_us > 0,
        "latency histogram recorded the served rounds"
    );
}

/// `PolicyClient` absorbs Busy backpressure transparently: two
/// 2-slot clients share a 2-slot pool with a slow backend — rounds
/// interleave through Busy/retry and both complete with no deadlock.
#[test]
fn policy_client_retries_busy_transparently() {
    let gauges = PipelineGauges::shared();
    let (mut server, backend) = start_stub_server(
        PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, 2)
            .with_slots(2)
            .with_admission(Duration::from_millis(1))
            .with_retry_after_ms(1)
            .with_batch_timeout(Duration::from_micros(100)),
        stub_params(),
        gauges.clone(),
        Duration::from_millis(2),
        None,
    );
    let addr = server.addr.to_string();

    const ROUNDS: usize = 20;
    let workers: Vec<JoinHandle<u64>> = (0..2u64)
        .map(|t| {
            let addrs = vec![addr.clone()];
            std::thread::spawn(move || {
                let seeds = [2 * t, 2 * t + 1];
                let mut client = PolicyClient::connect(&addrs, &seeds).unwrap();
                // generous Busy patience; NO reconnect budget — any
                // failover attempt would error loudly here
                client.set_busy_retry_limit(10_000);
                let obs = vec![t as f32 * 0.1; 2 * OBS_LEN];
                let mut actions = [0usize; 2];
                for _ in 0..ROUNDS {
                    client.act(&obs, &mut actions).unwrap();
                }
                client.busy_backoffs()
            })
        })
        .collect();
    let backoffs: u64 = workers.into_iter().map(|w| w.join().unwrap()).sum();
    server.shutdown();
    backend.join().unwrap();

    let snap = gauges.snapshot();
    assert_eq!(snap.serve_requests, 2 * ROUNDS as u64);
    assert_eq!(
        snap.serve_busy, backoffs,
        "every server-side Busy was absorbed by a client backoff, \
         invisibly to the caller"
    );
}

// ---------------------------------------------------------------------------
// Typed protocol errors (mirrors rpc_errors.rs for the serving tier)
// ---------------------------------------------------------------------------

fn start_plain_server() -> (PolicyServer, JoinHandle<()>) {
    start_stub_server(
        PolicyServerConfig::new(OBS_SHAPE, NUM_ACTIONS, 2)
            .with_slots(4)
            .with_batch_timeout(Duration::from_micros(100)),
        stub_params(),
        PipelineGauges::shared(),
        Duration::ZERO,
        None,
    )
}

#[test]
fn mono_hello_handshake_returns_typed_error() {
    let (mut server, backend) = start_plain_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    write_msg(
        &mut writer,
        &Msg::Hello {
            env: "catch".into(),
            seed: 0,
            wrappers: WrapperCfg::default(),
        },
    )
    .unwrap();
    match read_msg(&mut reader).unwrap() {
        Msg::Error { message } => {
            assert!(message.contains("expected HelloBatch"), "{message}")
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    server.shutdown();
    backend.join().unwrap();
}

#[test]
fn group_larger_than_slot_pool_rejected_at_handshake() {
    let (mut server, backend) = start_plain_server();
    let stream = TcpStream::connect(server.addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    // 9 slots > the 4-slot pool: must be a typed handshake error, not
    // a submit-time panic deep in the batcher
    write_msg(
        &mut writer,
        &Msg::HelloBatch {
            env: "policy".into(),
            seeds: (0..9).collect(),
            wrappers: WrapperCfg::default(),
        },
    )
    .unwrap();
    match read_msg(&mut reader).unwrap() {
        Msg::Error { message } => {
            assert!(
                message.contains("exceeds the inference slot pool"),
                "{message}"
            )
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    server.shutdown();
    backend.join().unwrap();
}

#[test]
fn undecodable_frame_returns_typed_error() {
    let (mut server, backend) = start_plain_server();
    let (mut w, mut r) = raw_policy_handshake(&server.addr.to_string(), &[1, 2]);
    // tag 250 is no known message
    write_frame(&mut w, &[250u8, 1, 2, 3]).unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(
                message.contains("expected ObsBatch") && message.contains("undecodable"),
                "{message}"
            )
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    server.shutdown();
    backend.join().unwrap();
}

#[test]
fn group_size_mismatch_returns_typed_error() {
    let (mut server, backend) = start_plain_server();
    // handshook for 2 slots, then a 3-slot ObsBatch arrives
    let (mut w, mut r) = raw_policy_handshake(&server.addr.to_string(), &[1, 2]);
    write_msg(&mut w, &obs_batch_msg(3, 0.0)).unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(
                message.contains("obs batch of 3 slots != expected 2"),
                "{message}"
            )
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    server.shutdown();
    backend.join().unwrap();
}

#[test]
fn wrong_frame_type_mid_stream_returns_typed_error() {
    let (mut server, backend) = start_plain_server();
    let (mut w, mut r) = raw_policy_handshake(&server.addr.to_string(), &[1]);
    // a decodable frame of the wrong kind: the error names it
    write_msg(&mut w, &Msg::ActionBatch { actions: vec![1] }).unwrap();
    match read_msg(&mut r).unwrap() {
        Msg::Error { message } => {
            assert!(
                message.contains("expected ObsBatch") && message.contains("ActionBatch"),
                "{message}"
            )
        }
        other => panic!("expected Error frame, got {other:?}"),
    }
    server.shutdown();
    backend.join().unwrap();
}
