//! End-to-end integration tests over the real AOT artifacts.
//!
//! These exercise the full three-layer stack: Rust coordinator →
//! PJRT-compiled HLO (JAX L2 + Pallas L1) → envs, in both mono and
//! poly (localhost env-server) modes.  They need `make artifacts` to
//! have produced `artifacts/catch`; they are skipped (with a loud
//! message) otherwise so `cargo test` stays runnable pre-artifacts.

use std::path::{Path, PathBuf};

use torchbeast::config::{Mode, TrainConfig};
use torchbeast::coordinator;
use torchbeast::runtime::{InferenceEngine, LearnerBatch, LearnerEngine};

fn artifact_dir(tag: &str) -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts").join(tag);
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts/{tag} missing (run `make artifacts`)");
        None
    }
}

fn base_cfg(tag: &str) -> Option<TrainConfig> {
    let dir = artifact_dir(tag)?;
    Some(TrainConfig {
        artifact_dir: dir,
        num_actors: 4,
        total_steps: 12,
        seed: 3,
        log_interval: 0,
        ..TrainConfig::default()
    })
}

#[test]
fn mono_training_runs_and_learns_shape() {
    let Some(cfg) = base_cfg("catch") else { return };
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 12);
    assert_eq!(report.history.len(), 12);
    // every loss is finite, frames flowed, episodes completed
    for row in &report.history {
        assert!(row.stats.total_loss().is_finite());
        assert!(row.stats.grad_norm().is_finite());
        assert!(row.stats.mean_rho() > 0.0);
    }
    // 12 steps x B=8 rollouts x T=20 steps = 1920 frames minimum
    assert!(report.frames >= 1920, "frames {}", report.frames);
    assert!(report.episodes > 0);
    assert!(!report.final_params.is_empty());
}

#[test]
fn poly_training_matches_pipeline_invariants() {
    let Some(mut cfg) = base_cfg("catch") else { return };
    cfg.mode = Mode::Poly; // spawns localhost env servers internally
    cfg.num_actors = 4;
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 12);
    assert!(report.frames >= 1920);
    assert!(report.batcher.requests as u64 >= report.frames);
    for row in &report.history {
        assert!(row.stats.total_loss().is_finite());
    }
}

/// Vectorized env groups through the real driver: `--envs_per_actor`
/// must train end to end in both modes, with the same pipeline
/// invariants as the classic pool (the B=1 path is covered by the two
/// tests above, unchanged).
#[test]
fn grouped_training_runs_in_both_modes() {
    let Some(cfg) = base_cfg("catch") else { return };
    for mode in [Mode::Mono, Mode::Poly] {
        let mut grouped = cfg.clone();
        grouped.mode = mode;
        grouped.envs_per_actor = 2; // 4 envs -> 2 groups of 2
        let report = coordinator::train(&grouped).unwrap();
        assert_eq!(report.steps, 12, "{mode:?}");
        assert!(report.frames >= 1920, "{mode:?}: frames {}", report.frames);
        assert!(report.episodes > 0, "{mode:?}");
        for row in &report.history {
            assert!(row.stats.total_loss().is_finite(), "{mode:?}");
        }
        // group size doesn't divide num_actors? still fine: 4 envs in
        // groups of 3 -> groups of 3 + 1
        let mut uneven = cfg.clone();
        uneven.mode = mode;
        uneven.envs_per_actor = 3;
        uneven.total_steps = 4;
        let report = coordinator::train(&uneven).unwrap();
        assert_eq!(report.steps, 4, "{mode:?} uneven groups");
    }
}

/// Experience replay through the real driver: with `--replay_capacity`
/// and `--replay_ratio` set, the stacker warms the ring from fresh
/// rollouts and then mixes sampled ones into every learner batch,
/// reported through `TrainReport::replay` and the shared gauges.
#[test]
fn replay_training_runs_and_reports() {
    let Some(mut cfg) = base_cfg("catch") else { return };
    cfg.replay_capacity = 8;
    cfg.replay_ratio = 0.25;
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 12);
    for row in &report.history {
        assert!(row.stats.total_loss().is_finite());
    }
    let rs = report.replay.expect("replay stats present when enabled");
    assert_eq!(rs.capacity, 8);
    assert_eq!(rs.len, 8, "8 rollouts fill the ring within the first batch");
    assert!(rs.inserted >= rs.len as u64);
    // warmup gate: batch 1 is all-fresh; every warmed batch samples
    // round(0.25 * B) = 2 replayed rollouts (B = 8 for this artifact)
    assert!(rs.sampled >= 2, "warmed batches must sample: {rs:?}");
    assert_eq!(rs.sampled % 2, 0, "each warmed batch samples exactly 2");
    // the gauges snapshot carries the same occupancy
    assert_eq!(report.gauges.replay_size, 8);
    // (the stacker may prefetch up to ~3 more batches — 6 samples —
    // between the pre-shutdown gauge snapshot and its own exit)
    assert!(report.gauges.replay_sampled >= rs.sampled.saturating_sub(6));

    // the default (capacity 0) carries no replay stats at all
    let Some(cfg0) = base_cfg("catch") else { return };
    let r0 = coordinator::train(&cfg0).unwrap();
    assert!(r0.replay.is_none());
    assert_eq!(r0.gauges.replay_size, 0);

    // misconfiguration is a loud up-front error, not a hang
    let Some(mut bad) = base_cfg("catch") else { return };
    bad.replay_ratio = 0.5; // capacity left at 0
    assert!(coordinator::train(&bad).is_err());
}

#[test]
fn params_update_every_step() {
    let Some(cfg) = base_cfg("catch") else { return };
    let mut learner = LearnerEngine::load(&cfg.artifact_dir).unwrap();
    let initial = learner.init_params(7).unwrap();
    let manifest = learner.manifest.clone();
    // synthetic batch: random-ish data through the full learner HLO
    let mut batch = LearnerBatch::zeros(&manifest);
    for (i, o) in batch.observations.iter_mut().enumerate() {
        *o = ((i * 2654435761) % 97) as f32 / 97.0;
    }
    for (i, a) in batch.actions.iter_mut().enumerate() {
        *a = (i % manifest.num_actions) as i32;
    }
    for (i, r) in batch.rewards.iter_mut().enumerate() {
        *r = if i % 5 == 0 { 1.0 } else { 0.0 };
    }
    let (stats, snap1) = learner.step(&batch).unwrap();
    assert!(stats.total_loss().is_finite());
    let moved = initial
        .iter()
        .zip(&snap1)
        .filter(|(a, b)| a != b)
        .count();
    assert_eq!(moved, initial.len(), "every leaf must move");
    // a second step moves them again (optimizer state advanced)
    let (_, snap2) = learner.step(&batch).unwrap();
    assert!(snap1.iter().zip(&snap2).any(|(a, b)| a != b));
}

#[test]
fn inference_partial_batches_match_full() {
    let Some(cfg) = base_cfg("catch") else { return };
    let mut engine = InferenceEngine::load(&cfg.artifact_dir).unwrap();
    let params = engine.init_params(11).unwrap();
    engine.set_params(&params, 2).unwrap();
    let m = engine.manifest.clone();
    let obs_len = m.obs_len();
    let bi = m.inference_batch;
    // full batch of distinct observations
    let obs: Vec<f32> = (0..bi * obs_len)
        .map(|i| ((i * 31) % 7) as f32 / 7.0)
        .collect();
    let (logits_full, base_full) = engine.infer(&obs, bi).unwrap();
    // partial batch: first 3 rows must match the full result rows
    let n = 3.min(bi);
    let (logits_part, base_part) = engine.infer(&obs[..n * obs_len], n).unwrap();
    let a = m.num_actions;
    for i in 0..n {
        for k in 0..a {
            let d = (logits_full[i * a + k] - logits_part[i * a + k]).abs();
            assert!(d < 1e-5, "row {i} logit {k} differs by {d}");
        }
        assert!((base_full[i] - base_part[i]).abs() < 1e-5);
    }
}

#[test]
fn init_is_deterministic_per_seed() {
    let Some(cfg) = base_cfg("catch") else { return };
    let mut a = LearnerEngine::load(&cfg.artifact_dir).unwrap();
    let mut b = LearnerEngine::load(&cfg.artifact_dir).unwrap();
    let pa = a.init_params(42).unwrap();
    let pb = b.init_params(42).unwrap();
    assert_eq!(pa, pb);
    let pc = a.init_params(43).unwrap();
    assert_ne!(pa, pc);
}

#[test]
fn mono_and_poly_same_seed_similar_start() {
    // The two data planes share artifact + seed: their first learner
    // losses should be in the same ballpark (identical params, same
    // env distribution — not bit-identical because actor/batch timing
    // interleaves differently).
    let Some(cfg) = base_cfg("catch") else { return };
    let mut mono = cfg.clone();
    mono.total_steps = 3;
    let mut poly = cfg.clone();
    poly.total_steps = 3;
    poly.mode = Mode::Poly;
    let rm = coordinator::train(&mono).unwrap();
    let rp = coordinator::train(&poly).unwrap();
    let lm = rm.history[0].stats.total_loss();
    let lp = rp.history[0].stats.total_loss();
    assert!(lm.is_finite() && lp.is_finite());
    let ratio = (lm / lp).abs();
    assert!(
        (0.05..20.0).contains(&ratio),
        "first-step losses wildly different: {lm} vs {lp}"
    );
}

#[test]
fn evaluate_runs_greedy_policy() {
    let Some(cfg) = base_cfg("catch") else { return };
    let mut learner = LearnerEngine::load(&cfg.artifact_dir).unwrap();
    let params = learner.init_params(5).unwrap();
    let mean =
        coordinator::evaluate(&cfg.artifact_dir, &params, 5, 1, &cfg.wrappers).unwrap();
    // catch returns are in [-1, 1]
    assert!((-1.0..=1.0).contains(&mean));
}

#[test]
fn evaluate_batched_reports_throughput() {
    let Some(cfg) = base_cfg("catch") else { return };
    let mut learner = LearnerEngine::load(&cfg.artifact_dir).unwrap();
    let params = learner.init_params(5).unwrap();
    // single stream (the old evaluate) vs the artifact's full batch
    let single = coordinator::evaluate_batched(
        &cfg.artifact_dir, &params, 6, 1, &cfg.wrappers, 1,
    )
    .unwrap();
    let batched = coordinator::evaluate_batched(
        &cfg.artifact_dir, &params, 6, 1, &cfg.wrappers, 0,
    )
    .unwrap();
    for r in [&single, &batched] {
        assert_eq!(r.episodes, 6);
        assert!(r.frames >= 6, "frames {}", r.frames);
        assert!(r.mean_return.is_finite());
        assert!(r.fps > 0.0);
    }
    assert!((single.mean_batch - 1.0).abs() < 1e-9, "eval_batch=1 is single-stream");
    assert!(
        batched.mean_batch > 1.0,
        "batched eval must actually batch inference: {}",
        batched.mean_batch
    );
}

/// Deterministic synthetic batch (contents derived from `salt` only),
/// shaped for the artifact's manifest — the fixed input that makes
/// sharded-vs-inline comparisons exact.
fn synthetic_batch(m: &torchbeast::runtime::Manifest, salt: usize) -> LearnerBatch {
    let mut batch = LearnerBatch::zeros(m);
    for (i, o) in batch.observations.iter_mut().enumerate() {
        *o = (((i + salt) * 2654435761) % 97) as f32 / 97.0;
    }
    for (i, a) in batch.actions.iter_mut().enumerate() {
        *a = ((i + salt) % m.num_actions) as i32;
    }
    for (i, r) in batch.rewards.iter_mut().enumerate() {
        *r = if (i + salt) % 5 == 0 { 1.0 } else { 0.0 };
    }
    batch
}

/// The `--num_learners 1` acceptance pin (DESIGN.md §Sharded-Learner):
/// one shard through the pool — step, degenerate average over n=1,
/// install — must reproduce the inline learner loop bit for bit on the
/// same batch sequence, with the *real* artifact engine on both sides.
#[test]
fn sharded_single_learner_matches_inline_loop() {
    use torchbeast::coordinator::batching_queue::batching_queue;
    use torchbeast::coordinator::learner_pool::ShardedLearner;

    let Some(cfg) = base_cfg("catch") else { return };
    let dir = cfg.artifact_dir.clone();
    let mut inline = LearnerEngine::load(&dir).unwrap();
    let init = inline.init_params(17).unwrap();
    let manifest = inline.manifest.clone();
    inline.set_params(&init).unwrap();

    let steps = 4usize;
    let mut inline_snaps = Vec::with_capacity(steps);
    for k in 0..steps {
        let (_, snap) = inline.step(&synthetic_batch(&manifest, k)).unwrap();
        inline_snaps.push(snap);
    }

    let (ret_tx, ret_rx) = batching_queue::<LearnerBatch>(2);
    let factory_init = init.clone();
    let pool = ShardedLearner::spawn(
        1,
        move |_idx| {
            let mut e = LearnerEngine::load(&dir)?;
            e.set_params(&factory_init)?;
            Ok(e)
        },
        ret_tx,
        None,
    )
    .unwrap();
    for (k, expect) in inline_snaps.iter().enumerate() {
        let result = pool
            .step_round(vec![synthetic_batch(&manifest, k)])
            .expect("round result");
        assert_eq!(
            &result.params, expect,
            "step {k}: one shard must be bit-identical to the inline loop"
        );
        let _ = ret_rx.recv();
    }
    pool.join().unwrap();
}

/// N=2 gradient-sync determinism with the real engine: two identical
/// runs over the same batch schedule must produce bit-identical
/// averaged parameter trajectories (fixed-order f32 reduction), and
/// the averaged run must differ from either shard stepping alone.
#[test]
fn sharded_two_learners_deterministic_with_real_engine() {
    use torchbeast::coordinator::batching_queue::batching_queue;
    use torchbeast::coordinator::learner_pool::ShardedLearner;

    let Some(cfg) = base_cfg("catch") else { return };
    let dir = cfg.artifact_dir.clone();
    let mut probe = LearnerEngine::load(&dir).unwrap();
    let init = probe.init_params(29).unwrap();
    let manifest = probe.manifest.clone();

    let steps = 3usize;
    let run = || {
        let dir = dir.clone();
        let factory_init = init.clone();
        let (ret_tx, ret_rx) = batching_queue::<LearnerBatch>(4);
        let pool = ShardedLearner::spawn(
            2,
            move |_idx| {
                let mut e = LearnerEngine::load(&dir)?;
                e.set_params(&factory_init)?;
                Ok(e)
            },
            ret_tx,
            None,
        )
        .unwrap();
        let mut snaps = Vec::with_capacity(steps);
        for k in 0..steps {
            // distinct batches per shard: the average is nontrivial
            let batches = vec![
                synthetic_batch(&manifest, 2 * k),
                synthetic_batch(&manifest, 2 * k + 1),
            ];
            snaps.push(pool.step_round(batches).expect("round result").params);
            for _ in 0..2 {
                let _ = ret_rx.recv();
            }
        }
        pool.join().unwrap();
        snaps
    };
    let a = run();
    let b = run();
    for (k, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x, y, "round {k} must reproduce bit-for-bit");
    }

    // the average is not either shard's solo trajectory
    probe.set_params(&init).unwrap();
    let (_, solo) = probe.step(&synthetic_batch(&manifest, 0)).unwrap();
    assert_ne!(a[0], solo, "two distinct batches must yield a true average");
}

/// Driver-level sharding acceptance: `--num_learners 2` trains end to
/// end, publishes one weight version per synchronized round, and the
/// policy-lag histogram in the gauges snapshot is populated with a
/// nonzero distribution (actors run behind the learner by design).
#[test]
fn sharded_training_reports_policy_lag() {
    let Some(mut cfg) = base_cfg("catch") else { return };
    cfg.num_learners = 2;
    let report = coordinator::train(&cfg).unwrap();
    assert_eq!(report.steps, 12);
    for row in &report.history {
        assert!(row.stats.total_loss().is_finite());
        assert!(row.stats.grad_norm().is_finite());
    }
    let g = report.gauges;
    assert!(g.lag_count > 0, "every consumed batch records its lag: {g:?}");
    assert!(
        g.lag_max >= 1,
        "12 rounds with in-flight unrolls must observe nonzero lag: {g:?}"
    );

    // staleness-bounded replay composes with sharding: the knob is
    // plumbed driver -> stacker -> ring, and the run still completes
    let Some(mut cfg2) = base_cfg("catch") else { return };
    cfg2.num_learners = 2;
    cfg2.replay_capacity = 8;
    cfg2.replay_ratio = 0.25;
    cfg2.replay_staleness = 4;
    let report2 = coordinator::train(&cfg2).unwrap();
    assert_eq!(report2.steps, 12);
    let rs = report2.replay.expect("replay stats present when enabled");
    assert!(rs.sampled > 0, "warmed batches must sample: {rs:?}");

    // the single-learner default also records lag (the histogram is
    // not a sharded-only feature)
    let Some(cfg1) = base_cfg("catch") else { return };
    let r1 = coordinator::train(&cfg1).unwrap();
    assert!(r1.gauges.lag_count > 0);
}

/// The telemetry acceptance gate: pool occupancy, learner-queue depth
/// and stacker prefetch lead are all visible in the TrainReport, and
/// the pre-shutdown snapshot accounts for every pooled buffer.
#[test]
fn train_report_exposes_pipeline_gauges() {
    let Some(cfg) = base_cfg("catch") else { return };
    let m = torchbeast::runtime::Manifest::load(&cfg.artifact_dir).unwrap();
    let report = coordinator::train(&cfg).unwrap();
    let g = report.gauges;
    // Snapshots derive rented = capacity - free from one atomic load,
    // so free + rented == max(free, capacity): this equality gates the
    // capacity gauge AND that the free count never over-counts past
    // it.  (Exact per-operation free-count accounting is gated by the
    // rollout-pool unit test against the pool's locked ground truth.)
    let pool_capacity = (cfg.num_actors + cfg.queue_capacity + m.batch_size) as u64;
    assert_eq!(
        g.pool_free + g.pool_rented,
        pool_capacity,
        "free gauge over-counted or capacity gauge wrong: {g:?}"
    );
    assert!(g.queue_depth <= cfg.queue_capacity as u64);
    assert!(g.batches_ready <= 2, "double-buffered prefetch is bounded");
    assert!(g.slots_in_use <= cfg.num_actors as u64);
}
