//! Deterministic byte-mutation fuzzing of the wire codec (DESIGN.md
//! §Static-Analysis; the adversarial-hardening rung of the roadmap).
//!
//! Every `decode_*` entry point in `rpc::codec` — plus the frame
//! reader — is driven with ≥10k mutated frames derived from a valid
//! corpus covering all nine [`Msg`] variants.  The contract under
//! attack: decoding hostile bytes yields `Ok` or a typed
//! `anyhow::Error`, never a panic (slice-index, `try_into`, arithmetic
//! overflow) and never an unbounded allocation (a forged length prefix
//! must not translate into a forged-length buffer).
//!
//! Mutations are seeded through the in-tree splitmix64 generator
//! ([`torchbeast::util::rng::Rng`]), so a failure reproduces exactly
//! from the printed seed.
//!
//! The binary installs the counting allocator and asserts the pooled
//! `decode_*_into` paths allocate **zero** times on success — the
//! same zero-alloc claim `tb-lint` fences statically — and only the
//! bounded error-construction allocations on failure.  This test is
//! the only one in the file: the allocation counter is process-global,
//! so it must not share the binary with concurrently-running tests.

use std::io::Cursor;

use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::rpc::codec::{
    decode_action, decode_action_batch_into, decode_obs_batch_into, decode_observation_into,
    frame_tag, read_frame, read_msg, write_frame, Msg, ObsHeader,
};
use torchbeast::util::counting_alloc::{allocations, CountingAllocator};
use torchbeast::util::rng::Rng;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

/// Mutated frames per decode entry point.
const ROUNDS: usize = 10_000;

/// Allocation budget for one failed decode: anyhow error construction
/// (message formatting + boxing) costs a handful of allocations; a
/// decode that scales allocations with a forged length field would
/// blow far past this.
const ERR_ALLOC_BUDGET: u64 = 64;

const OBS_LEN: usize = 8;
const GROUP: usize = 3;

fn corpus() -> Vec<Msg> {
    let header = |k: u32| ObsHeader {
        reward: 0.25 * k as f32,
        done: k % 2 == 0,
        episode_step: 10 + k,
        episode_return: 1.5 + k as f32,
    };
    vec![
        Msg::Hello {
            env: "catch".to_string(),
            seed: 7,
            wrappers: WrapperCfg::default(),
        },
        Msg::Spec {
            channels: 1,
            height: 10,
            width: 5,
            num_actions: 3,
        },
        Msg::Observation {
            reward: 0.5,
            done: true,
            episode_step: 12,
            episode_return: 3.25,
            obs: (0..OBS_LEN).map(|i| i as f32 * 0.5).collect(),
        },
        Msg::Action { action: 2 },
        Msg::Bye,
        Msg::Error {
            message: "unknown env".to_string(),
        },
        Msg::HelloBatch {
            env: "catch".to_string(),
            seeds: vec![1, 2, 3],
            wrappers: WrapperCfg::default(),
        },
        Msg::ObsBatch {
            headers: (0..GROUP as u32).map(header).collect(),
            obs: (0..GROUP * OBS_LEN).map(|i| i as f32).collect(),
        },
        Msg::ActionBatch {
            actions: vec![0, 1, 2],
        },
    ]
}

/// Apply one random mutation to `buf` in place.
fn mutate(rng: &mut Rng, buf: &mut Vec<u8>) {
    match rng.below(5) {
        // single-byte xor
        0 if !buf.is_empty() => {
            let i = rng.below(buf.len());
            buf[i] ^= (rng.next_u64() as u8) | 1;
        }
        // truncate
        1 if !buf.is_empty() => {
            let n = rng.below(buf.len());
            buf.truncate(n);
        }
        // extend with junk
        2 => {
            for _ in 0..=rng.below(16) {
                buf.push(rng.next_u64() as u8);
            }
        }
        // 4-byte overwrite: forged length/tag fields
        3 if buf.len() >= 4 => {
            let i = rng.below(buf.len() - 3);
            let v = (rng.next_u64() as u32).to_le_bytes();
            buf[i..i + 4].copy_from_slice(&v);
        }
        // fully random buffer
        _ => {
            let n = rng.below(64);
            buf.clear();
            for _ in 0..n {
                buf.push(rng.next_u64() as u8);
            }
        }
    }
}

/// Run every payload-level decoder over one (possibly hostile) payload,
/// asserting the zero-alloc decode paths hold their allocation budget.
fn drive_payload_decoders(
    payload: &[u8],
    obs_out: &mut [f32],
    headers_out: &mut [ObsHeader],
    batch_obs_out: &mut [f32],
    actions_out: &mut [u32],
) {
    // frame_tag: pure slice peek, zero allocations either way
    let before = allocations();
    let _ = frame_tag(payload);
    assert_eq!(allocations() - before, 0, "frame_tag allocated");

    // owning decoder: allocation is proportional to the decoded value;
    // the assertion here is simply "no panic"
    let _ = Msg::decode(payload);

    let before = allocations();
    let r = decode_observation_into(payload, obs_out);
    check_budget("decode_observation_into", r.is_ok(), allocations() - before);

    let before = allocations();
    let r = decode_action(payload);
    check_budget("decode_action", r.is_ok(), allocations() - before);

    let before = allocations();
    let r = decode_obs_batch_into(payload, headers_out, batch_obs_out);
    check_budget("decode_obs_batch_into", r.is_ok(), allocations() - before);

    let before = allocations();
    let r = decode_action_batch_into(payload, actions_out);
    check_budget("decode_action_batch_into", r.is_ok(), allocations() - before);
}

fn check_budget(path: &str, ok: bool, allocated: u64) {
    if ok {
        assert_eq!(allocated, 0, "{path} allocated {allocated}x on success");
    } else {
        assert!(
            allocated <= ERR_ALLOC_BUDGET,
            "{path} allocated {allocated}x constructing an error"
        );
    }
}

#[test]
fn fuzzed_frames_never_panic_or_overallocate() {
    let seed: u64 = 0x7b_c0de_c0de;
    let mut rng = Rng::new(seed);
    let encoded: Vec<Vec<u8>> = corpus().iter().map(Msg::encode).collect();

    let mut obs_out = [0.0f32; OBS_LEN];
    let mut headers_out = [ObsHeader::default(); GROUP];
    let mut batch_obs_out = [0.0f32; GROUP * OBS_LEN];
    let mut actions_out = [0u32; GROUP];

    // sanity: the unmutated corpus round-trips through the owning path
    for (msg, payload) in corpus().iter().zip(&encoded) {
        let decoded = Msg::decode(payload)
            .unwrap_or_else(|e| panic!("valid corpus frame failed to decode: {e}"));
        assert_eq!(&decoded, msg);
    }
    // ... and through each pooled decoder for its own frame type
    decode_observation_into(&encoded[2], &mut obs_out).expect("valid Observation frame");
    assert_eq!(decode_action(&encoded[3]).expect("valid Action frame"), 2);
    decode_obs_batch_into(&encoded[7], &mut headers_out, &mut batch_obs_out)
        .expect("valid ObsBatch frame");
    decode_action_batch_into(&encoded[8], &mut actions_out).expect("valid ActionBatch frame");

    // -- payload-level fuzzing: ROUNDS mutated frames per entry point --------
    let mut scratch: Vec<u8> = Vec::new();
    for round in 0..ROUNDS {
        scratch.clear();
        scratch.extend_from_slice(&encoded[round % encoded.len()]);
        // layer 1–3 mutations so compound corruption is covered too
        for _ in 0..=rng.below(3) {
            mutate(&mut rng, &mut scratch);
        }
        drive_payload_decoders(
            &scratch,
            &mut obs_out,
            &mut headers_out,
            &mut batch_obs_out,
            &mut actions_out,
        );
    }

    // -- frame-reader fuzzing: mutated *framed* byte streams -----------------
    let mut framed: Vec<u8> = Vec::new();
    let mut frame_scratch: Vec<u8> = Vec::new();
    for round in 0..ROUNDS {
        framed.clear();
        write_frame(&mut framed, &encoded[round % encoded.len()]).expect("in-memory write");
        for _ in 0..=rng.below(3) {
            mutate(&mut rng, &mut framed);
        }
        // read_frame must never trust the length prefix beyond the cap:
        // outcomes are a payload slice or a typed error, and scratch
        // stays bounded by MAX_FRAME
        let _ = read_frame(&mut Cursor::new(&framed[..]), &mut frame_scratch);
        // read_msg composes read_frame + Msg::decode over a fresh cursor
        let _ = read_msg(&mut Cursor::new(&framed[..]));
    }

    // the loops above prove: no panic across ROUNDS mutated frames per
    // decode path; print the seed so any future failure reproduces
    println!("fuzz_codec: {ROUNDS} rounds/path clean (seed {seed:#x})");
}
