//! Golden-vector test: pure-Rust V-trace vs the Python reference.
//!
//! `scripts/gen_vtrace_golden.py` runs `python/compile/kernels/ref.py`
//! over fixed seeds and commits the results to
//! `rust/tests/data/vtrace_golden.json`; this test replays the inputs
//! through `torchbeast::vtrace` and compares (experiment E8's
//! three-way agreement: ref.py == Pallas kernel == Rust).

use torchbeast::util::json::Json;
use torchbeast::vtrace;

fn unflatten_2d(flat: &[f64], t: usize, b: usize) -> Vec<Vec<f32>> {
    (0..t)
        .map(|ti| (0..b).map(|bi| flat[ti * b + bi] as f32).collect())
        .collect()
}

fn unflatten_3d(flat: &[f64], t: usize, b: usize, a: usize) -> Vec<Vec<Vec<f32>>> {
    (0..t)
        .map(|ti| {
            (0..b)
                .map(|bi| {
                    (0..a)
                        .map(|ai| flat[(ti * b + bi) * a + ai] as f32)
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn floats(j: &Json, key: &str) -> Vec<f64> {
    j.get(key)
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect()
}

#[test]
fn rust_vtrace_matches_python_reference() {
    // (the manifest dir IS rust/ — the old "rust/tests/..." join looked
    // for rust/rust/tests and could never find the fixture)
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/vtrace_golden.json");
    let text = std::fs::read_to_string(path).unwrap_or_else(|e| {
        panic!(
            "golden fixture missing at {path} ({e}) — regenerate it with \
             `python scripts/gen_vtrace_golden.py` from the repo root and \
             commit the JSON"
        )
    });
    let cases = Json::parse(&text).unwrap();
    let cases = cases.as_arr().unwrap();
    assert!(cases.len() >= 5);

    for (ci, case) in cases.iter().enumerate() {
        let t = case.get("T").unwrap().as_usize().unwrap();
        let b = case.get("B").unwrap().as_usize().unwrap();
        let a = case.get("A").unwrap().as_usize().unwrap();
        let clip_rho = case.get("clip_rho").unwrap().as_f64().unwrap() as f32;
        let clip_c = case.get("clip_c").unwrap().as_f64().unwrap() as f32;

        let behavior = unflatten_3d(&floats(case, "behavior_logits"), t, b, a);
        let target = unflatten_3d(&floats(case, "target_logits"), t, b, a);
        let actions_f = floats(case, "actions");
        let actions: Vec<Vec<usize>> = (0..t)
            .map(|ti| (0..b).map(|bi| actions_f[ti * b + bi] as usize).collect())
            .collect();
        let discounts = unflatten_2d(&floats(case, "discounts"), t, b);
        let rewards = unflatten_2d(&floats(case, "rewards"), t, b);
        let values = unflatten_2d(&floats(case, "values"), t, b);
        let bootstrap: Vec<f32> = floats(case, "bootstrap").iter().map(|&x| x as f32).collect();

        let out = vtrace::from_logits(
            &behavior, &target, &actions, &discounts, &rewards, &values, &bootstrap,
            clip_rho, clip_c,
        );

        let want_vs = unflatten_2d(&floats(case, "vs"), t, b);
        let want_pg = unflatten_2d(&floats(case, "pg_advantages"), t, b);
        for ti in 0..t {
            for bi in 0..b {
                let dv = (out.vs[ti][bi] - want_vs[ti][bi]).abs();
                let dp = (out.pg_advantages[ti][bi] - want_pg[ti][bi]).abs();
                assert!(
                    dv < 2e-4 && dp < 2e-4,
                    "case {ci} [{ti},{bi}]: vs {} vs {}, pg {} vs {}",
                    out.vs[ti][bi],
                    want_vs[ti][bi],
                    out.pg_advantages[ti][bi],
                    want_pg[ti][bi],
                );
            }
        }
    }
}
