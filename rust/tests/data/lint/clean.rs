//! Fixture: a file that must produce zero findings — fenced hot path
//! without allocation, fallible code without panicking accessors, and
//! a test module exercising every freedom test code is granted.

// tb-lint: no-alloc
fn hot(dst: &mut [f32], src: &[f32]) {
    dst.copy_from_slice(src);
}

fn fallible(x: Option<u32>) -> u32 {
    x.unwrap_or(0)
}

/// Doc text may mention `x.unwrap()` or `println!` freely; so may
/// strings: the scanner never reads needles out of either.
fn strings() -> &'static str {
    "a string saying vec![1] and .unwrap() is not code"
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_do_anything() {
        let v = vec![1, 2, 3];
        assert_eq!(v.clone().pop().unwrap(), 3);
        println!("tests may print");
    }
}
