//! Fixture: unjustified `.unwrap()` / `.expect(` in non-test code.

fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}

fn g(r: Result<u32, String>) -> u32 {
    r.expect("boom")
}

fn justified(x: Option<u32>) -> u32 {
    x.unwrap() // tb-lint: allow(unwrap, fixture: justified on this line)
}
