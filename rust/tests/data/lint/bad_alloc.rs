//! Fixture: allocating tokens inside a `no-alloc` fenced fn.

// tb-lint: no-alloc
fn hot(v: &[f32]) -> Vec<f32> {
    let copied = v.to_vec();
    let boxed = Box::new(copied.len());
    drop(boxed);
    copied
}

fn cold(v: &[f32]) -> Vec<f32> {
    v.to_vec()
}
