//! Fixture: raw print macros outside telemetry/ and main.rs.

fn report(x: u32) {
    println!("x = {x}");
    eprintln!("warning: {x}");
}
