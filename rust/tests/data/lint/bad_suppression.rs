//! Fixture: broken directives — unknown rule, unused allow, dangling fence.

fn f(x: Option<u32>) -> u32 {
    let y = 1; // tb-lint: allow(frobnicate, no such rule)
    let z = 2; // tb-lint: allow(unwrap, never fires on this line)
    x.unwrap_or(y + z)
}

// tb-lint: no-alloc
struct NotAFn;
