//! Fixture: `Ordering::SeqCst` without an inline reason comment.

use std::sync::atomic::{AtomicU32, Ordering};

static X: AtomicU32 = AtomicU32::new(0);

fn f() {
    X.store(1, Ordering::SeqCst);
    X.store(2, Ordering::SeqCst); // fence: pairs with the load in g()
}
