//! Fixture suite for `tb-lint` (DESIGN.md §Static-Analysis): known-bad
//! snippets under `tests/data/lint/` must produce exactly the seeded
//! diagnostics (rule + line), the clean fixture must produce none, and
//! the crate's own `src/` tree must lint clean — the same self-hosting
//! gate `scripts/ci.sh` enforces via the `tb_lint` binary.

use torchbeast::lint::{lint_source, lint_tree, lock_rank_findings, Rule};

/// Findings of a fixture as comparable `(rule, line)` pairs.
fn rules_at(file: &str, src: &str) -> Vec<(Rule, usize)> {
    lint_source(file, src)
        .into_iter()
        .map(|f| (f.rule, f.line))
        .collect()
}

#[test]
fn bad_alloc_fixture() {
    let src = include_str!("data/lint/bad_alloc.rs");
    let findings = lint_source("bad_alloc.rs", src);
    assert_eq!(
        findings
            .iter()
            .map(|f| (f.rule, f.line))
            .collect::<Vec<_>>(),
        vec![(Rule::Alloc, 5), (Rule::Alloc, 6)]
    );
    // exact diagnostics name the offending token
    assert_eq!(findings[0].message, "`to_vec` inside a no-alloc fenced fn");
    assert_eq!(
        findings[1].message,
        "`Box::new` inside a no-alloc fenced fn"
    );
    // the unfenced fn with the same token produced no finding
    assert!(findings.iter().all(|f| f.line < 10));
}

#[test]
fn bad_print_fixture() {
    let src = include_str!("data/lint/bad_print.rs");
    assert_eq!(
        rules_at("bad_print.rs", src),
        vec![(Rule::Print, 4), (Rule::Print, 5)]
    );
    // the same source is exempt under telemetry/ and main.rs
    assert_eq!(rules_at("telemetry/bad_print.rs", src), vec![]);
    assert_eq!(rules_at("main.rs", src), vec![]);
}

#[test]
fn bad_unwrap_fixture() {
    let src = include_str!("data/lint/bad_unwrap.rs");
    // lines 4 and 8 fire; line 12 is suppressed by its trailing allow
    assert_eq!(
        rules_at("bad_unwrap.rs", src),
        vec![(Rule::Unwrap, 4), (Rule::Unwrap, 8)]
    );
}

#[test]
fn bad_seqcst_fixture() {
    let src = include_str!("data/lint/bad_seqcst.rs");
    let findings = lint_source("bad_seqcst.rs", src);
    // line 8 has no reason comment; line 9's inline comment passes
    assert_eq!(
        findings
            .iter()
            .map(|f| (f.rule, f.line))
            .collect::<Vec<_>>(),
        vec![(Rule::Ordering, 8)]
    );
    assert_eq!(
        findings[0].message,
        "Ordering::SeqCst needs an inline reason comment"
    );
}

#[test]
fn bad_suppression_fixture() {
    let src = include_str!("data/lint/bad_suppression.rs");
    let findings = lint_source("bad_suppression.rs", src);
    assert_eq!(
        findings
            .iter()
            .map(|f| (f.rule, f.line))
            .collect::<Vec<_>>(),
        vec![
            (Rule::Suppression, 4),
            (Rule::Suppression, 5),
            (Rule::Suppression, 9),
        ]
    );
    assert!(findings[0].message.contains("unknown rule `frobnicate`"));
    assert_eq!(
        findings[1].message,
        "unused suppression: no `unwrap` finding here"
    );
    assert_eq!(
        findings[2].message,
        "dangling no-alloc fence (no fn follows it)"
    );
}

#[test]
fn clean_fixture_has_zero_findings() {
    let src = include_str!("data/lint/clean.rs");
    assert_eq!(rules_at("clean.rs", src), vec![]);
}

#[test]
fn finding_renders_file_line_rule() {
    let src = include_str!("data/lint/bad_print.rs");
    let findings = lint_source("sub/dir/bad_print.rs", src);
    assert_eq!(
        findings[0].to_string(),
        "sub/dir/bad_print.rs:4: [print] `println!` outside telemetry/ and main.rs — use tb_info!/tb_warn!"
    );
}

/// The cross-file lock-rank registry check (util/sync.rs rank table):
/// two locks sharing a rank is a finding that names the first
/// registration; unique ranks are clean.
#[test]
fn duplicate_lock_rank_across_files_is_a_finding() {
    let clean = [
        (
            "a.rs".to_string(),
            "const A: LockOrder = LockOrder::new(10, \"a\");\n".to_string(),
        ),
        (
            "b.rs".to_string(),
            "const B: LockOrder = LockOrder::new(90, \"b\");\n".to_string(),
        ),
    ];
    assert_eq!(lock_rank_findings(&clean), vec![]);

    let dup = [
        clean[0].clone(),
        (
            "c.rs".to_string(),
            "const C: LockOrder = LockOrder::new(10, \"c\");\n".to_string(),
        ),
    ];
    let findings = lock_rank_findings(&dup);
    assert_eq!(findings.len(), 1);
    assert_eq!(findings[0].rule, Rule::LockRank);
    assert_eq!(
        findings[0].to_string(),
        "c.rs:1: [lockrank] lock rank 10 already registered at a.rs:1 — \
         ranks must be globally unique (util/sync.rs rank table)"
    );
}

/// The self-hosting gate: the crate's own source tree must be clean.
/// This is the same check `cargo run --bin tb_lint` performs in CI,
/// kept as a test so `cargo test` alone catches regressions.
#[test]
fn src_tree_is_lint_clean() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let report = lint_tree(&root).expect("lint src tree");
    assert!(
        report.findings.is_empty(),
        "tb-lint findings in src/:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    // sanity: the walk really saw the tree, not an empty directory
    assert!(
        report.files >= 40,
        "expected the full source tree, scanned only {} files",
        report.files
    );
}
