//! Bench E2: end-to-end training throughput (FPS), mono vs poly, vs
//! actor count — regenerates the paper's §4 "on par in throughput"
//! comparison on this testbed.  Also reports the batcher's mean
//! request wait per run (the pooled hot path's latency contribution —
//! the before/after handle for the buffer-pool work) and the
//! stack/compute overlap of the double-buffered driver: `stack_ms` is
//! wall time the prefetch thread spent assembling batches, `wait_ms`
//! the time the learner actually stalled waiting for one.  Overlap is
//! working when wait ≪ stack (stacking hides behind learner compute
//! instead of adding to it).
//!
//! `cargo bench --bench throughput` (uses artifacts/catch).

use std::time::Instant;

use torchbeast::config::{Mode, TrainConfig};
use torchbeast::coordinator;
use torchbeast::util::stats::Bench;

struct Run {
    fps: f64,
    wait_us: f64,
    stack_ms: f64,
    learner_wait_ms: f64,
    learner_step_ms: f64,
}

fn run(mode: Mode, actors: usize, steps: u64) -> anyhow::Result<Run> {
    let cfg = TrainConfig {
        artifact_dir: "artifacts/catch".into(),
        mode,
        num_actors: actors,
        total_steps: steps,
        seed: 1,
        log_interval: 0,
        ..TrainConfig::default()
    };
    let t0 = Instant::now();
    let report = coordinator::train(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    Ok(Run {
        fps: report.frames as f64 / wall,
        wait_us: report.batcher.mean_wait_us(),
        stack_ms: report.stack_time.as_secs_f64() * 1e3,
        learner_wait_ms: report.learner_wait.as_secs_f64() * 1e3,
        learner_step_ms: report.learner_step_time.as_secs_f64() * 1e3 * report.steps as f64,
    })
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/catch/manifest.json").exists() {
        eprintln!("SKIP bench throughput: run `make artifacts` first");
        return Ok(());
    }
    let mut b = Bench::new("throughput (E2): end-to-end FPS, catch, 30 learner steps");
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>14} {:>14}",
        "actors", "mono_fps", "poly_fps", "ratio", "mono_wait_us", "poly_wait_us"
    );
    let mut overlap_rows = Vec::new();
    for &n in &[1usize, 2, 4, 8, 16] {
        let mono = run(Mode::Mono, n, 30)?;
        let poly = run(Mode::Poly, n, 30)?;
        println!(
            "{:>8} {:>12.0} {:>12.0} {:>10.2} {:>14.0} {:>14.0}",
            n,
            mono.fps,
            poly.fps,
            poly.fps / mono.fps,
            mono.wait_us,
            poly.wait_us
        );
        b.record(
            &format!("poly actors={n}"),
            1,
            std::time::Duration::from_secs_f64(1.0 / poly.fps.max(1e-9)),
        );
        overlap_rows.push((n, mono));
    }
    println!(
        "\n== stack/compute overlap (mono): prefetch thread vs learner stall ==\n\
         {:>8} {:>12} {:>12} {:>14} {:>14}",
        "actors", "stack_ms", "wait_ms", "learner_ms", "hidden_frac"
    );
    for (n, m) in &overlap_rows {
        // fraction of stacking wall time hidden behind learner compute
        let hidden = if m.stack_ms > 0.0 {
            1.0 - (m.learner_wait_ms / m.stack_ms).min(1.0)
        } else {
            1.0
        };
        println!(
            "{:>8} {:>12.2} {:>12.2} {:>14.2} {:>14.2}",
            n, m.stack_ms, m.learner_wait_ms, m.learner_step_ms, hidden
        );
        b.record(
            &format!("mono actors={n}"),
            1,
            std::time::Duration::from_secs_f64(1.0 / m.fps.max(1e-9)),
        );
    }
    b.report();
    println!("\n(rows are seconds-per-frame; see the fps table above)");
    Ok(())
}
