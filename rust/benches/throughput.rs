//! Bench E2: end-to-end training throughput (FPS), mono vs poly, vs
//! actor count — regenerates the paper's §4 "on par in throughput"
//! comparison on this testbed.  Also reports the batcher's mean
//! request wait per run (the pooled hot path's latency contribution —
//! the before/after handle for the buffer-pool work).
//!
//! `cargo bench --bench throughput` (uses artifacts/catch).

use std::time::Instant;

use torchbeast::config::{Mode, TrainConfig};
use torchbeast::coordinator;
use torchbeast::util::stats::Bench;

fn fps(mode: Mode, actors: usize, steps: u64) -> anyhow::Result<(f64, f64, f64)> {
    let cfg = TrainConfig {
        artifact_dir: "artifacts/catch".into(),
        mode,
        num_actors: actors,
        total_steps: steps,
        seed: 1,
        log_interval: 0,
        ..TrainConfig::default()
    };
    let t0 = Instant::now();
    let report = coordinator::train(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    Ok((
        report.frames as f64 / wall,
        report.batcher.mean_batch_size(),
        report.batcher.mean_wait_us(),
    ))
}

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/catch/manifest.json").exists() {
        eprintln!("SKIP bench throughput: run `make artifacts` first");
        return Ok(());
    }
    let mut b = Bench::new("throughput (E2): end-to-end FPS, catch, 30 learner steps");
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>14} {:>14}",
        "actors", "mono_fps", "poly_fps", "ratio", "mono_wait_us", "poly_wait_us"
    );
    for &n in &[1usize, 2, 4, 8, 16] {
        let (mono, _, mono_wait) = fps(Mode::Mono, n, 30)?;
        let (poly, _, poly_wait) = fps(Mode::Poly, n, 30)?;
        println!(
            "{:>8} {:>12.0} {:>12.0} {:>10.2} {:>14.0} {:>14.0}",
            n,
            mono,
            poly,
            poly / mono,
            mono_wait,
            poly_wait
        );
        b.record(
            &format!("mono actors={n}"),
            1,
            std::time::Duration::from_secs_f64(1.0 / mono.max(1e-9)),
        );
        b.record(
            &format!("poly actors={n}"),
            1,
            std::time::Duration::from_secs_f64(1.0 / poly.max(1e-9)),
        );
    }
    b.report();
    println!("\n(rows are seconds-per-frame; see the fps table above)");
    Ok(())
}
