//! Bench E2: end-to-end training throughput (FPS), mono vs poly, vs
//! actor count — regenerates the paper's §4 "on par in throughput"
//! comparison on this testbed.  Also reports the batcher's mean
//! request wait per run (the pooled hot path's latency contribution —
//! the before/after handle for the buffer-pool work) and the
//! stack/compute overlap of the double-buffered driver: `stack_ms` is
//! wall time the prefetch thread spent assembling batches, `wait_ms`
//! the time the learner actually stalled waiting for one.  Overlap is
//! working when wait ≪ stack (stacking hides behind learner compute
//! instead of adding to it).
//!
//! `cargo bench --bench throughput` (uses artifacts/catch).

use std::time::{Duration, Instant};

use torchbeast::config::{Mode, TrainConfig};
use torchbeast::coordinator;
use torchbeast::coordinator::actor_pool::{ActorConfig, ActorPool};
use torchbeast::coordinator::batching_queue::batching_queue;
use torchbeast::coordinator::dynamic_batcher::{dynamic_batcher, BatcherConfig};
use torchbeast::coordinator::rollout::{Rollout, RolloutPool};
use torchbeast::env::{self, Environment, LocalVecEnv, VecEnvironment};
use torchbeast::metrics::Metrics;
use torchbeast::util::stats::Bench;

struct Run {
    fps: f64,
    wait_us: f64,
    stack_ms: f64,
    learner_wait_ms: f64,
    learner_step_ms: f64,
}

fn run(mode: Mode, actors: usize, steps: u64) -> anyhow::Result<Run> {
    let cfg = TrainConfig {
        artifact_dir: "artifacts/catch".into(),
        mode,
        num_actors: actors,
        total_steps: steps,
        seed: 1,
        log_interval: 0,
        ..TrainConfig::default()
    };
    let t0 = Instant::now();
    let report = coordinator::train(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();
    Ok(Run {
        fps: report.frames as f64 / wall,
        wait_us: report.batcher.mean_wait_us(),
        stack_ms: report.stack_time.as_secs_f64() * 1e3,
        learner_wait_ms: report.learner_wait.as_secs_f64() * 1e3,
        learner_step_ms: report.learner_step_time.as_secs_f64() * 1e3 * report.steps as f64,
    })
}

/// Grouped-actor sampler throughput: drive `envs` catch envs through
/// the full actor→batcher→queue path with a stub inference thread (no
/// artifacts needed), grouped `envs_per_actor` per thread, and measure
/// env-steps/s over a fixed number of rollout batches.
fn grouped_run(envs: usize, envs_per_actor: usize, rollout_rounds: usize) -> GroupedRun {
    let t = 20;
    let spec = env::spec_of("catch").unwrap();
    let (obs_len, na) = (spec.obs_len(), spec.num_actions);
    let (client, stream) = dynamic_batcher(
        BatcherConfig::new(envs, Duration::from_micros(2000), obs_len, na).with_slots(envs),
    );
    let (tx, rx) = batching_queue::<Rollout>(2 * envs);
    let buffers = RolloutPool::new(4 * envs, t, obs_len, na);
    let metrics = Metrics::shared();
    let infer = std::thread::spawn(move || {
        let mut logits = Vec::new();
        let mut baselines = Vec::new();
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            logits.clear();
            logits.resize(n * na, 0.0);
            baselines.clear();
            baselines.resize(n, 0.0);
            batch.respond(&logits, &baselines, na).unwrap();
        }
    });
    let cfg = ActorConfig {
        unroll_length: t,
        num_actions: na,
        obs_len,
        seed: 1,
        first_id: 0,
        policy_version: torchbeast::coordinator::weights::VersionHandle::default(),
        heartbeat: torchbeast::telemetry::gauges::Counter::default(),
    };
    let n_threads;
    let pool = if envs_per_actor == 1 {
        n_threads = envs;
        let singles: Vec<Box<dyn Environment>> = (0..envs)
            .map(|id| env::make_env("catch", env::actor_seed(1, id)).unwrap())
            .collect();
        ActorPool::spawn(singles, client.clone(), tx, buffers.clone(), metrics, cfg)
    } else {
        let groups: Vec<Box<dyn VecEnvironment>> = (0..envs)
            .step_by(envs_per_actor)
            .map(|lo| {
                let hi = (lo + envs_per_actor).min(envs);
                let members: Vec<Box<dyn Environment>> = (lo..hi)
                    .map(|id| env::make_env("catch", env::actor_seed(1, id)).unwrap())
                    .collect();
                Box::new(LocalVecEnv::new(members).unwrap()) as Box<dyn VecEnvironment>
            })
            .collect();
        n_threads = groups.len();
        ActorPool::spawn_grouped(groups, client.clone(), tx, buffers.clone(), metrics, cfg)
    };
    let t0 = Instant::now();
    let mut frames = 0usize;
    for _ in 0..rollout_rounds {
        let batch = rx.recv_batch(envs).unwrap();
        frames += batch.len() * t;
        for r in batch {
            buffers.recycle(r);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    rx.close();
    client.shutdown_for_tests();
    buffers.close();
    pool.join();
    infer.join().unwrap();
    GroupedRun {
        sps: frames as f64 / wall,
        actor_threads: n_threads,
    }
}

struct GroupedRun {
    sps: f64,
    actor_threads: usize,
}

fn main() -> anyhow::Result<()> {
    // grouped-actor sampler comparison (stub policy; no artifacts):
    // the same 32-env workload, one thread per env vs one per group
    let envs = 32;
    println!(
        "== grouped actors (VecEnv): {envs} catch envs, stub inference ==\n\
         {:>14} {:>14} {:>14} {:>14} {:>10}",
        "envs_per_actor", "actor_threads", "env_steps_sec", "rendezvous", "speedup"
    );
    let mut base = 0.0f64;
    for &b in &[1usize, 8, 32] {
        let run = grouped_run(envs, b, 40);
        if b == 1 {
            base = run.sps;
        }
        println!(
            "{:>14} {:>14} {:>14.0} {:>14} {:>10.2}",
            b,
            run.actor_threads,
            run.sps,
            // batcher rendezvous per group step: 1 submit_slice vs B infer()s
            format!("1/{b} per env"),
            run.sps / base.max(1e-9),
        );
    }
    println!(
        "(grouped actors submit whole B-slices to the batcher — B x fewer\n\
         condvar rendezvous and threads for the same env traffic)\n"
    );

    if !std::path::Path::new("artifacts/catch/manifest.json").exists() {
        eprintln!("SKIP bench throughput: run `make artifacts` first");
        return Ok(());
    }
    let mut b = Bench::new("throughput (E2): end-to-end FPS, catch, 30 learner steps");
    println!(
        "{:>8} {:>12} {:>12} {:>10} {:>14} {:>14}",
        "actors", "mono_fps", "poly_fps", "ratio", "mono_wait_us", "poly_wait_us"
    );
    let mut overlap_rows = Vec::new();
    for &n in &[1usize, 2, 4, 8, 16] {
        let mono = run(Mode::Mono, n, 30)?;
        let poly = run(Mode::Poly, n, 30)?;
        println!(
            "{:>8} {:>12.0} {:>12.0} {:>10.2} {:>14.0} {:>14.0}",
            n,
            mono.fps,
            poly.fps,
            poly.fps / mono.fps,
            mono.wait_us,
            poly.wait_us
        );
        b.record(
            &format!("poly actors={n}"),
            1,
            std::time::Duration::from_secs_f64(1.0 / poly.fps.max(1e-9)),
        );
        overlap_rows.push((n, mono));
    }
    println!(
        "\n== stack/compute overlap (mono): prefetch thread vs learner stall ==\n\
         {:>8} {:>12} {:>12} {:>14} {:>14}",
        "actors", "stack_ms", "wait_ms", "learner_ms", "hidden_frac"
    );
    for (n, m) in &overlap_rows {
        // fraction of stacking wall time hidden behind learner compute
        let hidden = if m.stack_ms > 0.0 {
            1.0 - (m.learner_wait_ms / m.stack_ms).min(1.0)
        } else {
            1.0
        };
        println!(
            "{:>8} {:>12.2} {:>12.2} {:>14.2} {:>14.2}",
            n, m.stack_ms, m.learner_wait_ms, m.learner_step_ms, hidden
        );
        b.record(
            &format!("mono actors={n}"),
            1,
            std::time::Duration::from_secs_f64(1.0 / m.fps.max(1e-9)),
        );
    }
    b.report();
    println!("\n(rows are seconds-per-frame; see the fps table above)");
    Ok(())
}
