//! Bench: sharded-learner round throughput (DESIGN.md §Sharded-Learner).
//!
//! Artifact-free: stub shard engines (no xla) emulate a fixed per-step
//! compute cost with a calibrated spin, so the measurement isolates
//! what the sharding layer itself adds per round — the rank-50 barrier,
//! the fixed-order averaging of params + optimizer state, and worker
//! 0's publish to the weights store.  Each round steps one distinct
//! `B×T` batch per shard, so `learner_fps` scales ideally as N× the
//! single-shard figure; the gap from ideal is the sync cost.
//!
//! `cargo bench --bench shards`.  Pass `-- --json PATH` to also write
//! the machine-readable summary `scripts/bench.sh` collects into
//! `BENCH_7.json`.

use std::time::{Duration, Instant};

use torchbeast::coordinator::batching_queue::batching_queue;
use torchbeast::coordinator::learner_pool::{ShardEngine, ShardedLearner};
use torchbeast::coordinator::weights::WeightsStore;
use torchbeast::runtime::{LearnerBatch, LearnerStats, ParamVecs};

const UNROLL: usize = 20;
const BATCH: usize = 8;
/// f32s of parameter state averaged at the barrier each round (and the
/// same again of optimizer state) — sized like a small conv agent so
/// the reduction cost is visible, not vanishing.
const PARAM_LEN: usize = 64 * 1024;
/// Emulated engine compute per step, spent in a spin (a sleep would
/// let the OS quantize the round time and hide the barrier cost).
const STEP_COST: Duration = Duration::from_micros(300);

/// Host-only shard with realistic state volume: the step touches every
/// parameter (so averaging cannot be optimized away) and spins out the
/// emulated compute budget.
struct StubShard {
    params: ParamVecs,
    opt: ParamVecs,
}

impl StubShard {
    fn new() -> StubShard {
        StubShard {
            params: vec![vec![0.5f32; PARAM_LEN]],
            opt: vec![vec![0.0f32; PARAM_LEN]],
        }
    }
}

impl ShardEngine for StubShard {
    fn step_shard(
        &mut self,
        batch: &LearnerBatch,
    ) -> anyhow::Result<(LearnerStats, ParamVecs, ParamVecs)> {
        let t0 = Instant::now();
        let g = batch.rewards.iter().sum::<f32>() / batch.rewards.len() as f32;
        for (m, p) in self.opt[0].iter_mut().zip(self.params[0].iter_mut()) {
            *m = 0.9 * *m + g;
            *p -= 1e-4 * *m;
        }
        while t0.elapsed() < STEP_COST {
            std::hint::spin_loop();
        }
        let stats = LearnerStats { values: vec![g] };
        Ok((stats, self.params.clone(), self.opt.clone()))
    }

    fn install(&mut self, params: &ParamVecs, opt: &ParamVecs) -> anyhow::Result<()> {
        self.params = params.clone();
        self.opt = opt.clone();
        Ok(())
    }
}

fn mk_batch(reward: f32) -> LearnerBatch {
    let (t, b) = (UNROLL, BATCH);
    LearnerBatch {
        observations: vec![0.0; (t + 1) * b * 4],
        actions: vec![0; t * b],
        rewards: vec![reward; t * b],
        dones: vec![0.0; t * b],
        behavior_logits: vec![0.0; t * b * 3],
        policy_versions: vec![0; b],
    }
}

struct ShardRun {
    rounds_per_s: f64,
    learner_fps: f64,
    final_version: u64,
}

/// Drive `n` shards for `rounds` synchronized rounds through the full
/// production path: private input queues, barrier average, worker-0
/// publish, buffer return — exactly the driver's `step_round` loop.
fn shard_run(n: usize, rounds: usize) -> anyhow::Result<ShardRun> {
    let weights = WeightsStore::new();
    let (ret_tx, ret_rx) = batching_queue::<LearnerBatch>(2 * n);
    let pool = ShardedLearner::spawn(
        n,
        |_idx| Ok(StubShard::new()),
        ret_tx,
        Some(weights.clone()),
    )?;
    // warm: thread creation, first-touch of the param buffers
    for _ in 0..5 {
        let batches: Vec<LearnerBatch> = (0..n).map(|s| mk_batch(s as f32)).collect();
        pool.step_round(batches).expect("warmup round");
        for _ in 0..n {
            let _ = ret_rx.recv();
        }
    }
    let t0 = Instant::now();
    for k in 0..rounds {
        let batches: Vec<LearnerBatch> = (0..n).map(|s| mk_batch((k + s) as f32)).collect();
        pool.step_round(batches).expect("bench round");
        for _ in 0..n {
            let _ = ret_rx.recv();
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let final_version = weights.version();
    pool.join()?;
    weights.close();
    Ok(ShardRun {
        rounds_per_s: rounds as f64 / wall,
        learner_fps: (rounds * n * BATCH * UNROLL) as f64 / wall,
        final_version,
    })
}

fn main() -> anyhow::Result<()> {
    // optional machine-readable output: `-- --json PATH`
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--json" {
            i += 1;
            json_path = Some(
                args.get(i)
                    .ok_or_else(|| anyhow::anyhow!("--json needs a path"))?
                    .clone(),
            );
        }
        i += 1;
    }

    println!(
        "== sharded learner rounds: {PARAM_LEN} f32 params (+opt), \
         B={BATCH}, T={UNROLL}, {}us emulated step ==\n\
         {:>12} {:>12} {:>14} {:>10}",
        STEP_COST.as_micros(),
        "num_learners",
        "rounds/s",
        "learner_fps",
        "speedup"
    );
    let rounds = 200;
    let shard_counts = [1usize, 2];
    let mut runs = Vec::new();
    let mut base_fps = 0.0f64;
    for &n in &shard_counts {
        let run = shard_run(n, rounds)?;
        assert_eq!(
            run.final_version,
            (rounds + 5) as u64,
            "one publish per round (warmup included)"
        );
        if n == 1 {
            base_fps = run.learner_fps;
        }
        println!(
            "{:>12} {:>12.0} {:>14.0} {:>9.2}x",
            n,
            run.rounds_per_s,
            run.learner_fps,
            run.learner_fps / base_fps.max(1e-9),
        );
        runs.push((n, run));
    }
    println!(
        "(each round steps one distinct B*T batch per shard and averages\n\
         params + opt state at the barrier; ideal speedup is N x)"
    );

    if let Some(path) = json_path {
        let rows: Vec<String> = runs
            .iter()
            .map(|(n, r)| {
                format!(
                    "    {{\"num_learners\": {n}, \"rounds_per_s\": {:.1}, \
                     \"learner_fps\": {:.1}}}",
                    r.rounds_per_s, r.learner_fps
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"shards\",\n  \"param_len\": {PARAM_LEN},\n  \
             \"frames_per_batch\": {},\n  \"step_cost_us\": {},\n  \
             \"shard_fps\": [\n{}\n  ]\n}}\n",
            BATCH * UNROLL,
            STEP_COST.as_micros(),
            rows.join(",\n"),
        );
        std::fs::write(&path, json)?;
        println!("json summary written to {path}");
    }
    Ok(())
}
