//! Bench E4: the learner-queue (free/full-queue discipline, paper
//! §5.1) in isolation: enqueue/dequeue cycle cost and rollout-sized
//! payload handoff rates under producer/consumer contention.

use std::time::Instant;

use torchbeast::coordinator::batching_queue::batching_queue;
use torchbeast::util::stats::Bench;

fn handoff_rate(producers: usize, capacity: usize, payload: usize, items: usize) -> f64 {
    let (tx, rx) = batching_queue::<Vec<f32>>(capacity);
    let t0 = Instant::now();
    let handles: Vec<_> = (0..producers)
        .map(|_| {
            let tx = tx.clone();
            let per = items / producers;
            std::thread::spawn(move || {
                for _ in 0..per {
                    tx.send(vec![0.0f32; payload]).unwrap();
                }
            })
        })
        .collect();
    let consumer = std::thread::spawn(move || {
        let mut got = 0usize;
        let total = (items / producers) * producers;
        // batch dequeues must not exceed capacity: recv_batch(n) needs n
        // items resident at once, and producers block at `capacity`.
        let max_batch = 8.min(capacity);
        while got < total {
            let n = max_batch.min(total - got);
            got += rx.recv_batch(n).map(|b| b.len()).unwrap_or(0);
        }
    });
    for h in handles {
        h.join().unwrap();
    }
    consumer.join().unwrap();
    items as f64 / t0.elapsed().as_secs_f64()
}

fn main() {
    let mut b = Bench::new("queues (E4): batching_queue handoff");
    // rollout-sized payload: T=20 catch rollout ≈ 21*50 + 20*(1+1+1+3) floats ≈ 1.2k
    let rollout_floats = 1200;
    println!(
        "{:>10} {:>10} {:>14} {:>16}",
        "producers", "capacity", "payload_f32", "rollouts_per_s"
    );
    for &producers in &[1usize, 4, 16] {
        for &capacity in &[2usize, 16, 64] {
            let rate = handoff_rate(producers, capacity, rollout_floats, 10_000);
            println!(
                "{:>10} {:>10} {:>14} {:>16.0}",
                producers, capacity, rollout_floats, rate
            );
        }
    }

    // raw cycle cost without payload
    b.run("send+recv_batch(1), empty payload", || {
        let (tx, rx) = batching_queue::<u64>(4);
        for i in 0..64 {
            tx.send(i).unwrap();
            rx.recv_batch(1).unwrap();
        }
    });
    b.report();
    println!(
        "\npaper-shaped check: queue handoff is orders of magnitude faster than\n\
         env steps or inference — the queues are never the bottleneck (§5.1)."
    );
}
