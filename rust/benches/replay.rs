//! Bench: the experience-replay subsystem (DESIGN.md §Replay).
//!
//! Three measurements, all artifact-free (stub policy):
//!
//! 1. **Insert throughput** — ns per copy-in-place of a T-step
//!    rollout into a warmed (FIFO-evicting) ring slot.
//! 2. **Sample throughput** — ns per uniform draw + time-major stack
//!    of the sampled rollout into a learner-batch column.
//! 3. **End-to-end fps** — the full actor→batcher→queue→mixed-stacker
//!    pipeline at `replay_ratio` 0 / 0.25 / 0.5: env fps (fresh frames
//!    drained) vs learner fps (frames entering batches, replayed
//!    columns included — the sample-efficiency lever replay buys).
//!
//! `cargo bench --bench replay`.  Pass `-- --json PATH` to also write
//! the machine-readable summary `scripts/bench.sh` collects into
//! `BENCH_7.json`.

use std::time::{Duration, Instant};

use torchbeast::coordinator::actor_pool::{ActorConfig, ActorPool};
use torchbeast::coordinator::batching_queue::batching_queue;
use torchbeast::coordinator::dynamic_batcher::{dynamic_batcher, BatcherConfig};
use torchbeast::coordinator::replay::{stack_mixed, ReplayBuffer};
use torchbeast::coordinator::rollout::{stack_rollout_into, Rollout, RolloutPool};
use torchbeast::coordinator::weights::VersionHandle;
use torchbeast::env::{self, Environment};
use torchbeast::metrics::Metrics;
use torchbeast::runtime::manifest::{DType, LeafSpec};
use torchbeast::runtime::{LearnerBatch, Manifest};

const UNROLL: usize = 20;
const BATCH: usize = 8;
const ENVS: usize = 8;
const REPLAY_CAPACITY: usize = 256;

fn stub_manifest(obs_shape: [usize; 3], num_actions: usize) -> Manifest {
    Manifest {
        dir: std::path::PathBuf::new(),
        env: "catch".into(),
        model: "stub".into(),
        obs_shape,
        num_actions,
        unroll_length: UNROLL,
        batch_size: BATCH,
        inference_batch: ENVS,
        inference_sizes: vec![ENVS],
        param_count: 1,
        params: vec![LeafSpec {
            name: "w".into(),
            shape: vec![1],
            dtype: DType::F32,
        }],
        opt_state: vec![],
        stats_names: vec![],
        hyperparams: torchbeast::util::json::Json::Obj(vec![]),
        hlo_sha256: String::new(),
    }
}

/// A complete rollout of the manifest's shape (contents irrelevant).
fn filled_rollout(obs_len: usize, num_actions: usize) -> Rollout {
    let mut r = Rollout::new(UNROLL, obs_len, num_actions);
    let obs = vec![0.25f32; obs_len];
    let logits = vec![0.5f32; num_actions];
    for i in 0..=UNROLL {
        r.set_obs(i, &obs);
    }
    for i in 0..UNROLL {
        r.set_transition(i, i % num_actions, &logits, 0.0, i == UNROLL - 1);
    }
    r
}

/// Insert + sample micro-benchmarks on a warmed ring.
fn micro(obs_len: usize, num_actions: usize, m: &Manifest) -> (f64, f64) {
    let mut rb = ReplayBuffer::new(REPLAY_CAPACITY, UNROLL, obs_len, num_actions, 1);
    let r = filled_rollout(obs_len, num_actions);
    for _ in 0..REPLAY_CAPACITY {
        rb.insert(&r); // warm to capacity: every further insert evicts
    }
    let iters = 20_000u32;
    let t0 = Instant::now();
    for _ in 0..iters {
        rb.insert(&r);
    }
    let insert_ns = t0.elapsed().as_nanos() as f64 / iters as f64;

    let mut batch = LearnerBatch::zeros(m);
    let t0 = Instant::now();
    for i in 0..iters {
        let s = rb.sample().expect("warmed ring");
        stack_rollout_into(s, i as usize % BATCH, m, &mut batch);
    }
    let sample_ns = t0.elapsed().as_nanos() as f64 / iters as f64;
    (insert_ns, sample_ns)
}

struct MixRun {
    env_fps: f64,
    learner_fps: f64,
    sampled: u64,
}

/// End-to-end stub-policy pipeline at a given replay ratio: ENVS catch
/// actors → dynamic batcher → learner queue → mixed stacker (the
/// driver's exact composition: plan → drain fresh → stack_mixed →
/// insert + recycle), measured over `batches` learner batches.
fn mixed_run(ratio: f64, batches: usize) -> MixRun {
    let spec = env::spec_of("catch").unwrap();
    let (obs_len, na) = (spec.obs_len(), spec.num_actions);
    let m = stub_manifest(spec.obs_shape(), na);
    let (client, stream) = dynamic_batcher(
        BatcherConfig::new(ENVS, Duration::from_micros(2000), obs_len, na).with_slots(ENVS),
    );
    let (tx, rx) = batching_queue::<Rollout>(2 * BATCH);
    let buffers = RolloutPool::new(ENVS + 3 * BATCH, UNROLL, obs_len, na);
    let infer = std::thread::spawn(move || {
        let logits = vec![0.0f32; ENVS * na];
        let baselines = vec![0.0f32; ENVS];
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            batch
                .respond(&logits[..n * na], &baselines[..n], na)
                .unwrap();
        }
    });
    let envs: Vec<Box<dyn Environment>> = (0..ENVS)
        .map(|id| env::make_env("catch", env::actor_seed(1, id)).unwrap())
        .collect();
    let pool = ActorPool::spawn(
        envs,
        client.clone(),
        tx,
        buffers.clone(),
        Metrics::shared(),
        ActorConfig {
            unroll_length: UNROLL,
            num_actions: na,
            obs_len,
            seed: 1,
            first_id: 0,
            policy_version: VersionHandle::default(),
            heartbeat: torchbeast::telemetry::gauges::Counter::default(),
        },
    );

    let mut replay = ReplayBuffer::new(2 * BATCH, UNROLL, obs_len, na, 9);
    let mut scratch: Vec<Rollout> = Vec::with_capacity(BATCH);
    let mut batch = LearnerBatch::zeros(&m);
    let mut env_frames = 0usize;
    let t0 = Instant::now();
    for _ in 0..batches {
        let replayed = replay.plan(BATCH, ratio);
        assert!(rx.recv_batch_into(BATCH - replayed, &mut scratch));
        stack_mixed(&scratch, &mut replay, replayed, &m, &mut batch);
        for r in scratch.drain(..) {
            replay.insert(&r);
            buffers.recycle(r);
        }
        env_frames += (BATCH - replayed) * UNROLL;
    }
    let wall = t0.elapsed().as_secs_f64();
    let sampled = replay.stats().sampled;
    rx.close();
    client.shutdown_for_tests();
    buffers.close();
    pool.join();
    infer.join().unwrap();
    MixRun {
        env_fps: env_frames as f64 / wall,
        learner_fps: (batches * BATCH * UNROLL) as f64 / wall,
        sampled,
    }
}

fn main() -> anyhow::Result<()> {
    // optional machine-readable output: `-- --json PATH`
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--json" {
            i += 1;
            json_path = Some(
                args.get(i)
                    .ok_or_else(|| anyhow::anyhow!("--json needs a path"))?
                    .clone(),
            );
        }
        i += 1;
    }

    let spec = env::spec_of("catch").unwrap();
    let m = stub_manifest(spec.obs_shape(), spec.num_actions);
    let (insert_ns, sample_ns) = micro(spec.obs_len(), spec.num_actions, &m);
    println!(
        "== replay ring (capacity {REPLAY_CAPACITY}, T={UNROLL}, catch obs) ==\n\
         {:>24} {:>12.0} ns\n{:>24} {:>12.0} ns",
        "insert (copy-in-place)", insert_ns, "sample + stack column", sample_ns
    );

    println!(
        "\n== end-to-end mixed stacking: {ENVS} catch envs, stub policy, \
         B={BATCH}, T={UNROLL} ==\n\
         {:>12} {:>12} {:>14} {:>12} {:>10}",
        "replay_ratio", "env_fps", "learner_fps", "sampled", "reuse"
    );
    let batches = 60;
    let ratios = [0.0f64, 0.25, 0.5];
    let mut runs = Vec::new();
    for &ratio in &ratios {
        let run = mixed_run(ratio, batches);
        println!(
            "{:>12.2} {:>12.0} {:>14.0} {:>12} {:>10.2}",
            ratio,
            run.env_fps,
            run.learner_fps,
            run.sampled,
            run.learner_fps / run.env_fps.max(1e-9),
        );
        runs.push((ratio, run));
    }
    println!(
        "(reuse = learner frames per fresh env frame: 1/(1 − ratio) once the\n\
         ring is warm — the sample-efficiency lever replay buys)"
    );

    if let Some(path) = json_path {
        let fps_rows: Vec<String> = runs
            .iter()
            .map(|(ratio, r)| {
                format!(
                    "    {{\"replay_ratio\": {ratio}, \"env_fps\": {:.1}, \
                     \"learner_fps\": {:.1}, \"sampled\": {}}}",
                    r.env_fps, r.learner_fps, r.sampled
                )
            })
            .collect();
        let json = format!(
            "{{\n  \"bench\": \"replay\",\n  \"frames_per_step\": {},\n  \
             \"replay_insert_ns\": {insert_ns:.1},\n  \
             \"replay_sample_ns\": {sample_ns:.1},\n  \"fps\": [\n{}\n  ]\n}}\n",
            BATCH * UNROLL,
            fps_rows.join(",\n"),
        );
        std::fs::write(&path, json)?;
        println!("json summary written to {path}");
    }
    Ok(())
}
