//! Bench: the span tracer's record path (DESIGN.md §Tracing).
//!
//! Three measurements, all artifact-free:
//!
//! 1. **Histogram-only span** — ns per `span(..).finish()` with ring
//!    buffering off (the always-on cost every pipeline stage pays:
//!    two clock reads + a handful of relaxed atomics).
//! 2. **Buffered span** — the same with ring buffering on
//!    (`--trace_path` mode): histogram record plus the single-producer
//!    ring push.  The budget is **< 50 ns/span** — cheap enough to
//!    leave the instrumentation on in production runs.
//! 3. **Drain throughput** — ns per event for the sampler-side ring
//!    drain that feeds the Chrome-trace writer.
//!
//! `cargo bench --bench trace`.  Pass `-- --json PATH` to also write
//! the machine-readable summary `scripts/bench.sh` collects into
//! `BENCH_10.json`.

use std::time::Instant;

use torchbeast::telemetry::trace::{self, Stage, RING_CAPACITY};

/// ns per span over `iters` spans of `stage` on this thread.
fn span_ns(stage: Stage, iters: u32) -> f64 {
    let t0 = Instant::now();
    for _ in 0..iters {
        trace::span(stage).finish();
    }
    t0.elapsed().as_nanos() as f64 / iters as f64
}

fn main() -> anyhow::Result<()> {
    // optional machine-readable output: `-- --json PATH`
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--json" {
            i += 1;
            json_path = Some(
                args.get(i)
                    .ok_or_else(|| anyhow::anyhow!("--json needs a path"))?
                    .clone(),
            );
        }
        i += 1;
    }

    let iters = 2_000_000u32;

    // 1. always-on path: stage histogram + last-completed marker only
    trace::set_ring_buffering(false);
    span_ns(Stage::EnvStep, iters / 10); // warm the clock + hist cache lines
    let hist_ns = span_ns(Stage::EnvStep, iters);

    // 2. --trace_path mode: histogram + single-producer ring push.  The
    // first buffered span registers this thread's ring (the one
    // allocation), inside the warm-up.
    trace::set_ring_buffering(true);
    span_ns(Stage::EnvStep, iters / 10);
    let ring_ns = span_ns(Stage::EnvStep, iters);
    trace::set_ring_buffering(false);

    // 3. sampler-side drain: refill the ring to capacity, then time the
    // copy-out (the steady-state export cost per event).
    trace::set_ring_buffering(true);
    for _ in 0..RING_CAPACITY {
        trace::span(Stage::EnvStep).finish();
    }
    trace::set_ring_buffering(false);
    let mut out = Vec::with_capacity(2 * RING_CAPACITY);
    let t0 = Instant::now();
    trace::drain_spans(&mut out);
    let drained = out.len().max(1);
    let drain_ns = t0.elapsed().as_nanos() as f64 / drained as f64;

    println!(
        "== span tracer ({iters} spans/measurement, ring capacity {RING_CAPACITY}) ==\n\
         {:>28} {:>10.1} ns\n{:>28} {:>10.1} ns\n{:>28} {:>10.1} ns  ({drained} events)",
        "histogram-only span", hist_ns, "buffered span (ring on)", ring_ns, "drain per event",
        drain_ns
    );
    let budget = 50.0;
    println!(
        "budget: < {budget:.0} ns per buffered span — {}",
        if ring_ns < budget { "met" } else { "MISSED" }
    );

    if let Some(path) = json_path {
        let json = format!(
            "{{\n  \"bench\": \"trace\",\n  \"span_iters\": {iters},\n  \
             \"span_hist_ns\": {hist_ns:.1},\n  \
             \"span_ring_ns\": {ring_ns:.1},\n  \
             \"drain_ns_per_event\": {drain_ns:.1},\n  \
             \"budget_ns\": {budget:.0},\n  \
             \"budget_met\": {}\n}}\n",
            ring_ns < budget
        );
        std::fs::write(&path, json)?;
        println!("json summary written to {path}");
    }
    Ok(())
}
