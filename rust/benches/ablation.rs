//! Ablation bench (DESIGN.md §Perf): the fused learner step with the
//! Pallas V-trace kernel vs the plain-XLA (`jax.lax.scan`) lowering.
//!
//! On CPU both lower to loop-ish HLO, so this measures interpret-mode
//! overhead rather than TPU benefit — the claim under test is that the
//! Pallas path costs *nothing material* on the learner step (the conv
//! net dominates), while buying the TPU-shaped structure documented in
//! DESIGN.md §Hardware-Adaptation.  Numerics must agree exactly.

use std::path::Path;

use torchbeast::runtime::tensor::{literal_to_f32s, upload_f32, upload_i32, upload_scalar_i32};
use torchbeast::runtime::{LearnerBatch, Manifest, Module};
use torchbeast::util::rng::Rng;
use torchbeast::util::stats::Bench;

fn main() -> anyhow::Result<()> {
    let dir = Path::new("artifacts/catch");
    if !dir.join("learner_nopallas.hlo.txt").exists() {
        eprintln!("SKIP bench ablation: re-run `make artifacts` (needs learner_nopallas)");
        return Ok(());
    }
    let manifest = Manifest::load(dir)?;
    let client = xla::PjRtClient::cpu()?;
    let init = Module::load(&client, "init", &dir.join("init.hlo.txt"))?;
    let learner = Module::load(&client, "learner", &dir.join("learner.hlo.txt"))?;
    let nopallas = Module::load(&client, "learner_nopallas", &dir.join("learner_nopallas.hlo.txt"))?;

    // params from init, zero opt state, synthetic batch
    let seed = upload_scalar_i32(&client, 1)?;
    let param_lits = init.run_buffers(&[&seed])?;
    let params: Vec<xla::PjRtBuffer> = param_lits
        .iter()
        .zip(&manifest.params)
        .map(|(lit, l)| upload_f32(&client, &literal_to_f32s(lit).unwrap(), &l.shape))
        .collect::<anyhow::Result<_>>()?;
    let opt: Vec<xla::PjRtBuffer> = manifest
        .opt_state
        .iter()
        .map(|l| upload_f32(&client, &vec![0.0f32; l.elems()], &l.shape))
        .collect::<anyhow::Result<_>>()?;

    let (t, b, a) = (manifest.unroll_length, manifest.batch_size, manifest.num_actions);
    let [c, h, w] = manifest.obs_shape;
    let mut rng = Rng::new(3);
    let mut batch = LearnerBatch::zeros(&manifest);
    for o in batch.observations.iter_mut() {
        *o = rng.next_f32();
    }
    for x in batch.actions.iter_mut() {
        *x = rng.below(a) as i32;
    }
    for r in batch.rewards.iter_mut() {
        *r = if rng.chance(0.2) { 1.0 } else { 0.0 };
    }
    for l in batch.behavior_logits.iter_mut() {
        *l = rng.next_f32() - 0.5;
    }
    let extra = [
        upload_f32(&client, &batch.observations, &[t + 1, b, c, h, w])?,
        upload_i32(&client, &batch.actions, &[t, b])?,
        upload_f32(&client, &batch.rewards, &[t, b])?,
        upload_f32(&client, &batch.dones, &[t, b])?,
        upload_f32(&client, &batch.behavior_logits, &[t, b, a])?,
    ];
    let mut refs: Vec<&xla::PjRtBuffer> = params.iter().chain(opt.iter()).collect();
    refs.extend(extra.iter());

    // numerics: the stats vectors must agree
    let out_p = learner.run_buffers(&refs)?;
    let out_n = nopallas.run_buffers(&refs)?;
    let stats_p = literal_to_f32s(out_p.last().unwrap())?;
    let stats_n = literal_to_f32s(out_n.last().unwrap())?;
    let mut max_diff = 0.0f32;
    for (x, y) in stats_p.iter().zip(&stats_n) {
        max_diff = max_diff.max((x - y).abs() / x.abs().max(1.0));
    }
    println!("learner stats pallas vs nopallas (rel): max diff {max_diff:.2e}");
    println!("  pallas   : {stats_p:?}");
    println!("  nopallas : {stats_n:?}");
    assert!(max_diff < 1e-3, "ablation numerics diverged");

    let mut bench = Bench::new("ablation: fused learner step, Pallas vs plain-XLA V-trace");
    bench.run(&format!("learner (pallas)    T={t} B={b}"), || {
        std::hint::black_box(learner.run_buffers(&refs).unwrap());
    });
    bench.run(&format!("learner (no pallas) T={t} B={b}"), || {
        std::hint::black_box(nopallas.run_buffers(&refs).unwrap());
    });
    bench.report();
    println!(
        "\nclaim under test: V-trace is a negligible slice of the learner step\n\
         either way (the conv fwd+bwd dominates); the Pallas path costs nothing\n\
         material on CPU while giving the TPU-shaped kernel structure."
    );
    Ok(())
}
