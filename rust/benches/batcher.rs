//! Bench E3: dynamic batcher in isolation (paper §5.2's batching
//! module): enqueue→result latency and achieved batch size as a
//! function of actor count and timeout.  No XLA — a stub inference
//! function with a configurable service time stands in for the model.

use std::time::{Duration, Instant};

use torchbeast::coordinator::dynamic_batcher::dynamic_batcher;
use torchbeast::util::stats::Summary;

fn scenario(actors: usize, timeout_us: u64, service_us: u64, per_actor: usize) -> (f64, f64, f64, f64) {
    let (client, stream) = dynamic_batcher(32, Duration::from_micros(timeout_us));
    let infer = std::thread::spawn(move || {
        let mut sizes = Summary::new();
        while let Some(batch) = stream.next_batch() {
            // emulate model evaluation cost
            let t0 = Instant::now();
            while t0.elapsed() < Duration::from_micros(service_us) {
                std::hint::spin_loop();
            }
            sizes.add(batch.len() as f64);
            let n = batch.len();
            batch.respond(&vec![0.0; n * 4], &vec![0.0; n], 4);
        }
        sizes
    });
    let t0 = Instant::now();
    let handles: Vec<_> = (0..actors)
        .map(|_| {
            let c = client.clone();
            std::thread::spawn(move || {
                let mut lat = Summary::new();
                for _ in 0..per_actor {
                    let t = Instant::now();
                    c.infer(vec![0.0; 50]).unwrap();
                    lat.add(t.elapsed().as_micros() as f64);
                }
                lat
            })
        })
        .collect();
    let mut lat = Summary::new();
    for h in handles {
        let s = h.join().unwrap();
        for i in 0..s.len() {
            lat.add(s.percentile(100.0 * i as f64 / s.len().max(1) as f64));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    client.shutdown_for_tests();
    let sizes = infer.join().unwrap();
    let throughput = (actors * per_actor) as f64 / wall;
    (lat.p50(), lat.p99(), sizes.mean(), throughput)
}

fn main() {
    println!("== bench batcher (E3): stub service 200µs, 32 max batch ==");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "actors", "timeout_us", "p50_lat_us", "p99_lat_us", "mean_batch", "req_per_sec"
    );
    for &actors in &[1usize, 4, 8, 16, 32, 64] {
        for &timeout in &[100u64, 1000, 5000] {
            let (p50, p99, mean_b, tput) = scenario(actors, timeout, 200, 200);
            println!(
                "{:>8} {:>12} {:>12.0} {:>12.0} {:>12.2} {:>14.0}",
                actors, timeout, p50, p99, mean_b, tput
            );
        }
    }
    println!(
        "\npaper-shaped checks: batch size grows with actors; latency bounded\n\
         by timeout under low load; throughput scales until service-bound."
    );
}
