//! Bench E3: dynamic batcher in isolation (paper §5.2's batching
//! module): enqueue→result latency and achieved batch size as a
//! function of actor count and timeout.  No XLA — a stub inference
//! function with a configurable service time stands in for the model.
//!
//! Also measures the buffer-pool claim directly: a counting global
//! allocator differences heap allocations across the steady-state
//! window; after warm-up (slot pool + batch storage + stats rings
//! filled) the per-request hot path must allocate **zero** times.

use std::time::{Duration, Instant};

use torchbeast::coordinator::dynamic_batcher::{dynamic_batcher, BatcherConfig};
use torchbeast::util::counting_alloc::{allocations, CountingAllocator};
use torchbeast::util::stats::Summary;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

const OBS_LEN: usize = 50;
const NUM_ACTIONS: usize = 4;

fn scenario(actors: usize, timeout_us: u64, service_us: u64, per_actor: usize) -> (f64, f64, f64, f64) {
    let (client, stream) = dynamic_batcher(
        BatcherConfig::new(32, Duration::from_micros(timeout_us), OBS_LEN, NUM_ACTIONS)
            .with_slots(actors.max(32)),
    );
    let infer = std::thread::spawn(move || {
        let mut sizes = Summary::new();
        let logits = vec![0.0f32; 32 * NUM_ACTIONS];
        let baselines = vec![0.0f32; 32];
        while let Some(batch) = stream.next_batch() {
            // emulate model evaluation cost
            let t0 = Instant::now();
            while t0.elapsed() < Duration::from_micros(service_us) {
                std::hint::spin_loop();
            }
            sizes.add(batch.len() as f64);
            let n = batch.len();
            batch
                .respond(&logits[..n * NUM_ACTIONS], &baselines[..n], NUM_ACTIONS)
                .unwrap();
        }
        sizes
    });
    let t0 = Instant::now();
    let handles: Vec<_> = (0..actors)
        .map(|_| {
            let c = client.clone();
            std::thread::spawn(move || {
                let obs = [0.0f32; OBS_LEN];
                let mut logits = Vec::with_capacity(NUM_ACTIONS);
                let mut lat = Summary::new();
                for _ in 0..per_actor {
                    let t = Instant::now();
                    c.infer(&obs, &mut logits).unwrap();
                    lat.add(t.elapsed().as_micros() as f64);
                }
                lat
            })
        })
        .collect();
    let mut lat = Summary::new();
    for h in handles {
        let s = h.join().unwrap();
        for i in 0..s.len() {
            lat.add(s.percentile(100.0 * i as f64 / s.len().max(1) as f64));
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    client.close();
    let sizes = infer.join().unwrap();
    let throughput = (actors * per_actor) as f64 / wall;
    (lat.p50(), lat.p99(), sizes.mean(), throughput)
}

/// Steady-state allocation audit: warm the pools, then count heap
/// allocations across a long request window — the pooled hot path
/// (slot checkout → in-place obs write → gather → scatter) must not
/// allocate at all.
fn alloc_probe(actors: usize, per_actor: usize, warmup: usize) {
    let (client, stream) = dynamic_batcher(
        BatcherConfig::new(8, Duration::from_micros(200), OBS_LEN, NUM_ACTIONS)
            .with_slots(actors.max(8)),
    );
    let total = actors * per_actor;
    let infer = std::thread::spawn(move || {
        let logits = vec![0.0f32; 8 * NUM_ACTIONS];
        let baselines = vec![0.0f32; 8];
        let mut served = 0usize;
        let mut window_start: Option<(u64, usize)> = None;
        while let Some(batch) = stream.next_batch() {
            let n = batch.len();
            batch
                .respond(&logits[..n * NUM_ACTIONS], &baselines[..n], NUM_ACTIONS)
                .unwrap();
            served += n;
            if window_start.is_none() && served >= warmup {
                window_start = Some((allocations(), served));
            }
        }
        let (a0, s0) = window_start.expect("warmup longer than the run");
        (allocations() - a0, served - s0)
    });
    let handles: Vec<_> = (0..actors)
        .map(|_| {
            let c = client.clone();
            std::thread::spawn(move || {
                let obs = [0.25f32; OBS_LEN];
                let mut logits = Vec::with_capacity(NUM_ACTIONS);
                for _ in 0..per_actor {
                    c.infer(&obs, &mut logits).unwrap();
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    client.close();
    let (allocs, requests) = infer.join().unwrap();
    let per_request = allocs as f64 / requests.max(1) as f64;
    println!(
        "steady state: {allocs} heap allocations over {requests} requests \
         ({per_request:.4} per request; {total} total requests, {warmup} warm-up)"
    );
    assert!(
        per_request < 0.01,
        "batcher hot path is allocating again: {per_request:.4} allocs/request"
    );
}

fn main() {
    println!("== bench batcher (E3): stub service 200µs, 32 max batch ==");
    println!(
        "{:>8} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "actors", "timeout_us", "p50_lat_us", "p99_lat_us", "mean_batch", "req_per_sec"
    );
    for &actors in &[1usize, 4, 8, 16, 32, 64] {
        for &timeout in &[100u64, 1000, 5000] {
            let (p50, p99, mean_b, tput) = scenario(actors, timeout, 200, 200);
            println!(
                "{:>8} {:>12} {:>12.0} {:>12.0} {:>12.2} {:>14.0}",
                actors, timeout, p50, p99, mean_b, tput
            );
        }
    }
    println!("\n== allocation audit: pooled slots + recycled batch buffers ==");
    alloc_probe(4, 5000, 2000);
    println!(
        "\npaper-shaped checks: batch size grows with actors; latency bounded\n\
         by timeout under low load; throughput scales until service-bound;\n\
         zero per-request heap allocations at steady state."
    );
}
