//! Bench: batched greedy-evaluation throughput — the ROADMAP item
//! "`evaluate` runs single-stream inference; batch it across
//! episodes" made measurable.  `eval_batch=1` reproduces the old
//! single-stream evaluate; larger batches share one bucketed
//! inference call across all active episode streams, so fps should
//! climb with the batch until the artifact's inference batch caps it.
//! `mean_batch` shows the realized slot utilization.
//!
//! `cargo bench --bench eval` (uses artifacts/catch; SKIPs without).

use torchbeast::config::TrainConfig;
use torchbeast::coordinator;
use torchbeast::runtime::LearnerEngine;
use torchbeast::util::stats::Bench;

fn main() -> anyhow::Result<()> {
    if !std::path::Path::new("artifacts/catch/manifest.json").exists() {
        eprintln!("SKIP bench eval: run `make artifacts` first");
        return Ok(());
    }
    let cfg = TrainConfig {
        artifact_dir: "artifacts/catch".into(),
        ..TrainConfig::default()
    };
    let mut learner = LearnerEngine::load(&cfg.artifact_dir)?;
    let params = learner.init_params(7)?;

    let episodes = 64;
    let mut b = Bench::new("eval: batched greedy evaluation, catch, 64 episodes");
    println!(
        "{:>10} {:>10} {:>12} {:>12} {:>12}",
        "eval_batch", "episodes", "fps", "mean_batch", "mean_return"
    );
    for &batch in &[1usize, 2, 4, 8, 0] {
        let r = coordinator::evaluate_batched(
            &cfg.artifact_dir,
            &params,
            episodes,
            1,
            &cfg.wrappers,
            batch,
        )?;
        let label = if batch == 0 {
            "auto".to_string()
        } else {
            batch.to_string()
        };
        println!(
            "{:>10} {:>10} {:>12.0} {:>12.2} {:>12.3}",
            label, r.episodes, r.fps, r.mean_batch, r.mean_return
        );
        b.record(
            &format!("eval_batch={label}"),
            r.frames as usize,
            r.elapsed,
        );
    }
    b.report();
    println!("(rows are per-frame; fps climbs with eval_batch — batching works)");
    Ok(())
}
