//! Bench E5: environment serving over TCP (the gRPC-substitute layer,
//! paper §5.2): round-trip latency per step and aggregate steps/s as
//! connections per server grow.
//!
//! With the pooled codec buffers (reusable frame read/write scratch on
//! both ends, observation decode straight into the caller's buffer)
//! the steady-state step exchange should allocate nothing; a counting
//! global allocator audits that alongside the latency numbers.
//!
//! The final section benches the policy-server tier: PolicyClients
//! submitting B-slot observation groups to a standalone PolicyServer
//! that coalesces them into backend batches.  Pass `-- --json PATH`
//! to also write the machine-readable summary `scripts/bench.sh`
//! collects into `BENCH_8.json`.

use std::time::{Duration, Instant};

use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::env::{Environment, SlotStep, VecEnvironment};
use torchbeast::rpc::{EnvServer, RemoteEnv, RemoteVecEnv};
use torchbeast::serving::{run_inference_loop, PolicyClient, PolicyServer, PolicyServerConfig};
use torchbeast::util::counting_alloc::{allocations, CountingAllocator};
use torchbeast::util::stats::Summary;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() -> anyhow::Result<()> {
    // optional machine-readable output: `-- --json PATH`
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut json_path: Option<String> = None;
    let mut i = 0;
    while i < args.len() {
        if args[i] == "--json" {
            i += 1;
            json_path = Some(
                args.get(i)
                    .ok_or_else(|| anyhow::anyhow!("--json needs a path"))?
                    .clone(),
            );
        }
        i += 1;
    }

    let server = EnvServer::start("127.0.0.1:0")?;
    let addr = server.addr.to_string();

    // single-stream round-trip latency + steady-state allocation audit
    let mut env = RemoteEnv::connect(&addr, "catch", 0, &WrapperCfg::default())?;
    let mut obs = vec![0.0; env.spec().obs_len()];
    env.reset(&mut obs);
    // warm-up: fill both ends' frame buffers before counting
    for i in 0..500 {
        if env.step(i % 3, &mut obs).done {
            env.reset(&mut obs);
        }
    }
    let mut lat = Summary::new();
    let a0 = allocations();
    let steps = 2000;
    for i in 0..steps {
        let t0 = Instant::now();
        let st = env.step(i % 3, &mut obs);
        lat.add(t0.elapsed().as_micros() as f64);
        if st.done {
            env.reset(&mut obs);
        }
    }
    let allocs = allocations() - a0;
    println!("== bench rpc (E5) ==");
    println!(
        "single stream step round-trip: p50 {:.0} µs  p99 {:.0} µs  mean {:.0} µs",
        lat.p50(),
        lat.p99(),
        lat.mean()
    );
    // `lat` itself pushes one f64 per step (amortized growth); allow
    // those reallocations and nothing more.
    println!(
        "steady state: {allocs} heap allocations over {steps} steps \
         ({:.4} per step incl. the bench's own stats vector)",
        allocs as f64 / steps as f64
    );
    assert!(
        (allocs as f64) < 0.05 * steps as f64,
        "rpc step path is allocating per frame again: {allocs} allocs / {steps} steps"
    );

    // aggregate throughput vs parallel streams
    println!("\n{:>10} {:>16} {:>18}", "streams", "steps_per_sec", "per_stream_sps");
    for &streams in &[1usize, 2, 4, 8, 16, 32] {
        let per_stream = 1000;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..streams)
            .map(|s| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut env =
                        RemoteEnv::connect(&addr, "catch", s as u64, &WrapperCfg::default())
                            .unwrap();
                    let mut obs = vec![0.0; env.spec().obs_len()];
                    env.reset(&mut obs);
                    for i in 0..per_stream {
                        if env.step(i % 3, &mut obs).done {
                            env.reset(&mut obs);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = (streams * per_stream) as f64;
        println!(
            "{:>10} {:>16.0} {:>18.0}",
            streams,
            total / wall,
            total / wall / streams as f64
        );
    }
    println!(
        "\npaper-shaped check: aggregate steps/s scales with streams (a thread\n\
         per stream — the §5.3 GIL ceiling that motivated PolyBeast's C++\n\
         server does not exist here)."
    );

    // batched streams (VecEnv protocol): the same 32-env workload as
    // one group per stream vs one env per stream.  A group of B costs
    // 2 wire frames and 1 server thread per step for all B envs.
    println!(
        "\n== batched streams (VecEnv protocol): 32 envs x {} steps each ==",
        BATCH_STEPS
    );
    println!(
        "{:>10} {:>10} {:>16} {:>16} {:>14} {:>10}",
        "group_B", "streams", "env_steps_sec", "frames_per_step", "srv_threads", "speedup"
    );
    let total_envs = 32usize;
    let mut base_sps = 0.0f64;
    for &b in &[1usize, 8, 32] {
        let server = EnvServer::start("127.0.0.1:0")?;
        let addr = server.addr.to_string();
        let n_groups = total_envs / b;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_groups)
            .map(|g| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let seeds: Vec<u64> = (0..b as u64).map(|s| (g as u64) * 100 + s).collect();
                    let mut venv =
                        RemoteVecEnv::connect(&addr, "catch", &seeds, &WrapperCfg::default())
                            .unwrap();
                    let l = venv.spec().obs_len();
                    let na = venv.spec().num_actions;
                    let mut obs = vec![0.0f32; b * l];
                    let mut steps = vec![SlotStep::default(); b];
                    let mut actions = vec![0usize; b];
                    venv.reset_all(&mut obs);
                    for i in 0..BATCH_STEPS {
                        for (s, a) in actions.iter_mut().enumerate() {
                            *a = (i + s) % na;
                        }
                        venv.step_batch(&actions, &mut obs, &mut steps);
                    }
                    assert!(venv.last_error().is_none());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let env_steps = (total_envs * BATCH_STEPS) as f64;
        let sps = env_steps / wall;
        if b == 1 {
            base_sps = sps;
        }
        // one ActionBatch + one ObsBatch per group round-trip
        let frames_per_env_step = 2.0 / b as f64;
        let streams = server
            .connections
            .load(std::sync::atomic::Ordering::Relaxed);
        println!(
            "{:>10} {:>10} {:>16.0} {:>16.3} {:>14} {:>10.2}",
            b,
            streams,
            sps,
            frames_per_env_step,
            streams, // one server thread per stream
            sps / base_sps.max(1e-9),
        );
        assert_eq!(streams as usize, n_groups, "one stream per group");
        assert_eq!(
            server
                .steps_served
                .load(std::sync::atomic::Ordering::Relaxed) as usize,
            total_envs * BATCH_STEPS
        );
    }
    println!(
        "\npaper-shaped check: B=32 should beat B=1 on env-steps/s with 32x\n\
         fewer wire frames per env step and 32x fewer server threads (the\n\
         rlpyt/TorchRL vectorized-sampler result, reproduced over TCP)."
    );

    // served inference (policy-server tier): streams of PolicyClients
    // submit B-slot observation groups to a standalone PolicyServer
    // which coalesces them into backend batches (tags 7-10 inverted:
    // the client ships obs, the server answers sampled actions).
    println!(
        "\n== served inference (policy-server tier): {} rounds per stream ==",
        SERVE_ROUNDS
    );
    println!(
        "{:>10} {:>10} {:>16} {:>12} {:>12}",
        "streams", "group_B", "actions_sec", "p50_us", "p99_us"
    );
    let obs_shape = [1usize, 2, 3];
    let obs_len: usize = obs_shape.iter().product();
    let num_actions = 4usize;
    let mut served_rows = Vec::new();
    for &streams in &[1usize, 4, 8] {
        for &b in &[1usize, 4] {
            // max_batch = streams * B lets one wave of concurrent
            // requests coalesce into a single backend batch; the short
            // timeout flushes stragglers.
            let cfg = PolicyServerConfig::new(obs_shape, num_actions, streams * b)
                .with_batch_timeout(Duration::from_micros(200));
            let mut server = PolicyServer::start("127.0.0.1:0", cfg)?;
            let batches = server.take_batch_stream().unwrap();
            let backend = std::thread::spawn(move || {
                run_inference_loop(&batches, num_actions, |obs, n, logits, baselines| {
                    logits.clear();
                    logits.resize(n * num_actions, 0.0);
                    baselines.clear();
                    baselines.resize(n, 0.0);
                    for (s, bl) in baselines.iter_mut().enumerate() {
                        let sum: f32 = obs[s * obs_len..(s + 1) * obs_len].iter().sum();
                        *bl = sum;
                        for (a, l) in logits[s * num_actions..(s + 1) * num_actions]
                            .iter_mut()
                            .enumerate()
                        {
                            *l = sum * 0.1 + a as f32 * 0.01;
                        }
                    }
                    Ok(())
                })
            });
            let addr = server.addr.to_string();
            let t0 = Instant::now();
            let handles: Vec<_> = (0..streams)
                .map(|g| {
                    let addr = addr.clone();
                    std::thread::spawn(move || {
                        let seeds: Vec<u64> =
                            (0..b as u64).map(|s| (g as u64) * 100 + s).collect();
                        let mut client =
                            PolicyClient::connect(std::slice::from_ref(&addr), &seeds).unwrap();
                        let obs = vec![0.25f32; b * obs_len];
                        let mut actions = vec![0usize; b];
                        let mut lat = Vec::with_capacity(SERVE_ROUNDS);
                        for _ in 0..SERVE_ROUNDS {
                            let t = Instant::now();
                            client.act(&obs, &mut actions).unwrap();
                            lat.push(t.elapsed().as_micros() as f64);
                        }
                        lat
                    })
                })
                .collect();
            let mut slat = Summary::new();
            for h in handles {
                for v in h.join().unwrap() {
                    slat.add(v);
                }
            }
            let wall = t0.elapsed().as_secs_f64();
            let actions_sec = (streams * b * SERVE_ROUNDS) as f64 / wall;
            server.shutdown();
            backend.join().unwrap()?;
            println!(
                "{:>10} {:>10} {:>16.0} {:>12.0} {:>12.0}",
                streams,
                b,
                actions_sec,
                slat.p50(),
                slat.p99()
            );
            served_rows.push(format!(
                "    {{\"streams\": {streams}, \"group_B\": {b}, \
                 \"actions_per_sec\": {:.1}, \"p50_us\": {:.1}, \"p99_us\": {:.1}}}",
                actions_sec,
                slat.p50(),
                slat.p99()
            ));
        }
    }
    println!(
        "\npaper-shaped check: served actions/s grows with streams while the\n\
         backend sees ~one coalesced batch per wave (the PolyBeast dynamic\n\
         batching result, served out-of-process)."
    );

    if let Some(path) = json_path {
        let json = format!(
            "{{\n  \"bench\": \"rpc\",\n  \"env_step_p50_us\": {:.1},\n  \
             \"env_step_p99_us\": {:.1},\n  \"served\": [\n{}\n  ]\n}}\n",
            lat.p50(),
            lat.p99(),
            served_rows.join(",\n"),
        );
        std::fs::write(&path, json)?;
        println!("json summary written to {path}");
    }
    Ok(())
}

/// Steps per env in the batched-stream comparison.
const BATCH_STEPS: usize = 1000;

/// Act rounds per client stream in the served-inference sweep.
const SERVE_ROUNDS: usize = 400;
