//! Bench E5: environment serving over TCP (the gRPC-substitute layer,
//! paper §5.2): round-trip latency per step and aggregate steps/s as
//! connections per server grow.
//!
//! With the pooled codec buffers (reusable frame read/write scratch on
//! both ends, observation decode straight into the caller's buffer)
//! the steady-state step exchange should allocate nothing; a counting
//! global allocator audits that alongside the latency numbers.

use std::time::Instant;

use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::env::{Environment, SlotStep, VecEnvironment};
use torchbeast::rpc::{EnvServer, RemoteEnv, RemoteVecEnv};
use torchbeast::util::counting_alloc::{allocations, CountingAllocator};
use torchbeast::util::stats::Summary;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() -> anyhow::Result<()> {
    let server = EnvServer::start("127.0.0.1:0")?;
    let addr = server.addr.to_string();

    // single-stream round-trip latency + steady-state allocation audit
    let mut env = RemoteEnv::connect(&addr, "catch", 0, &WrapperCfg::default())?;
    let mut obs = vec![0.0; env.spec().obs_len()];
    env.reset(&mut obs);
    // warm-up: fill both ends' frame buffers before counting
    for i in 0..500 {
        if env.step(i % 3, &mut obs).done {
            env.reset(&mut obs);
        }
    }
    let mut lat = Summary::new();
    let a0 = allocations();
    let steps = 2000;
    for i in 0..steps {
        let t0 = Instant::now();
        let st = env.step(i % 3, &mut obs);
        lat.add(t0.elapsed().as_micros() as f64);
        if st.done {
            env.reset(&mut obs);
        }
    }
    let allocs = allocations() - a0;
    println!("== bench rpc (E5) ==");
    println!(
        "single stream step round-trip: p50 {:.0} µs  p99 {:.0} µs  mean {:.0} µs",
        lat.p50(),
        lat.p99(),
        lat.mean()
    );
    // `lat` itself pushes one f64 per step (amortized growth); allow
    // those reallocations and nothing more.
    println!(
        "steady state: {allocs} heap allocations over {steps} steps \
         ({:.4} per step incl. the bench's own stats vector)",
        allocs as f64 / steps as f64
    );
    assert!(
        (allocs as f64) < 0.05 * steps as f64,
        "rpc step path is allocating per frame again: {allocs} allocs / {steps} steps"
    );

    // aggregate throughput vs parallel streams
    println!("\n{:>10} {:>16} {:>18}", "streams", "steps_per_sec", "per_stream_sps");
    for &streams in &[1usize, 2, 4, 8, 16, 32] {
        let per_stream = 1000;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..streams)
            .map(|s| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut env =
                        RemoteEnv::connect(&addr, "catch", s as u64, &WrapperCfg::default())
                            .unwrap();
                    let mut obs = vec![0.0; env.spec().obs_len()];
                    env.reset(&mut obs);
                    for i in 0..per_stream {
                        if env.step(i % 3, &mut obs).done {
                            env.reset(&mut obs);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = (streams * per_stream) as f64;
        println!(
            "{:>10} {:>16.0} {:>18.0}",
            streams,
            total / wall,
            total / wall / streams as f64
        );
    }
    println!(
        "\npaper-shaped check: aggregate steps/s scales with streams (a thread\n\
         per stream — the §5.3 GIL ceiling that motivated PolyBeast's C++\n\
         server does not exist here)."
    );

    // batched streams (VecEnv protocol): the same 32-env workload as
    // one group per stream vs one env per stream.  A group of B costs
    // 2 wire frames and 1 server thread per step for all B envs.
    println!(
        "\n== batched streams (VecEnv protocol): 32 envs x {} steps each ==",
        BATCH_STEPS
    );
    println!(
        "{:>10} {:>10} {:>16} {:>16} {:>14} {:>10}",
        "group_B", "streams", "env_steps_sec", "frames_per_step", "srv_threads", "speedup"
    );
    let total_envs = 32usize;
    let mut base_sps = 0.0f64;
    for &b in &[1usize, 8, 32] {
        let server = EnvServer::start("127.0.0.1:0")?;
        let addr = server.addr.to_string();
        let n_groups = total_envs / b;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..n_groups)
            .map(|g| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let seeds: Vec<u64> = (0..b as u64).map(|s| (g as u64) * 100 + s).collect();
                    let mut venv =
                        RemoteVecEnv::connect(&addr, "catch", &seeds, &WrapperCfg::default())
                            .unwrap();
                    let l = venv.spec().obs_len();
                    let na = venv.spec().num_actions;
                    let mut obs = vec![0.0f32; b * l];
                    let mut steps = vec![SlotStep::default(); b];
                    let mut actions = vec![0usize; b];
                    venv.reset_all(&mut obs);
                    for i in 0..BATCH_STEPS {
                        for (s, a) in actions.iter_mut().enumerate() {
                            *a = (i + s) % na;
                        }
                        venv.step_batch(&actions, &mut obs, &mut steps);
                    }
                    assert!(venv.last_error().is_none());
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let env_steps = (total_envs * BATCH_STEPS) as f64;
        let sps = env_steps / wall;
        if b == 1 {
            base_sps = sps;
        }
        // one ActionBatch + one ObsBatch per group round-trip
        let frames_per_env_step = 2.0 / b as f64;
        let streams = server
            .connections
            .load(std::sync::atomic::Ordering::Relaxed);
        println!(
            "{:>10} {:>10} {:>16.0} {:>16.3} {:>14} {:>10.2}",
            b,
            streams,
            sps,
            frames_per_env_step,
            streams, // one server thread per stream
            sps / base_sps.max(1e-9),
        );
        assert_eq!(streams as usize, n_groups, "one stream per group");
        assert_eq!(
            server
                .steps_served
                .load(std::sync::atomic::Ordering::Relaxed) as usize,
            total_envs * BATCH_STEPS
        );
    }
    println!(
        "\npaper-shaped check: B=32 should beat B=1 on env-steps/s with 32x\n\
         fewer wire frames per env step and 32x fewer server threads (the\n\
         rlpyt/TorchRL vectorized-sampler result, reproduced over TCP)."
    );
    Ok(())
}

/// Steps per env in the batched-stream comparison.
const BATCH_STEPS: usize = 1000;
