//! Bench E5: environment serving over TCP (the gRPC-substitute layer,
//! paper §5.2): round-trip latency per step and aggregate steps/s as
//! connections per server grow.
//!
//! With the pooled codec buffers (reusable frame read/write scratch on
//! both ends, observation decode straight into the caller's buffer)
//! the steady-state step exchange should allocate nothing; a counting
//! global allocator audits that alongside the latency numbers.

use std::time::Instant;

use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::env::Environment;
use torchbeast::rpc::{EnvServer, RemoteEnv};
use torchbeast::util::counting_alloc::{allocations, CountingAllocator};
use torchbeast::util::stats::Summary;

#[global_allocator]
static ALLOC: CountingAllocator = CountingAllocator;

fn main() -> anyhow::Result<()> {
    let server = EnvServer::start("127.0.0.1:0")?;
    let addr = server.addr.to_string();

    // single-stream round-trip latency + steady-state allocation audit
    let mut env = RemoteEnv::connect(&addr, "catch", 0, &WrapperCfg::default())?;
    let mut obs = vec![0.0; env.spec().obs_len()];
    env.reset(&mut obs);
    // warm-up: fill both ends' frame buffers before counting
    for i in 0..500 {
        if env.step(i % 3, &mut obs).done {
            env.reset(&mut obs);
        }
    }
    let mut lat = Summary::new();
    let a0 = allocations();
    let steps = 2000;
    for i in 0..steps {
        let t0 = Instant::now();
        let st = env.step(i % 3, &mut obs);
        lat.add(t0.elapsed().as_micros() as f64);
        if st.done {
            env.reset(&mut obs);
        }
    }
    let allocs = allocations() - a0;
    println!("== bench rpc (E5) ==");
    println!(
        "single stream step round-trip: p50 {:.0} µs  p99 {:.0} µs  mean {:.0} µs",
        lat.p50(),
        lat.p99(),
        lat.mean()
    );
    // `lat` itself pushes one f64 per step (amortized growth); allow
    // those reallocations and nothing more.
    println!(
        "steady state: {allocs} heap allocations over {steps} steps \
         ({:.4} per step incl. the bench's own stats vector)",
        allocs as f64 / steps as f64
    );
    assert!(
        (allocs as f64) < 0.05 * steps as f64,
        "rpc step path is allocating per frame again: {allocs} allocs / {steps} steps"
    );

    // aggregate throughput vs parallel streams
    println!("\n{:>10} {:>16} {:>18}", "streams", "steps_per_sec", "per_stream_sps");
    for &streams in &[1usize, 2, 4, 8, 16, 32] {
        let per_stream = 1000;
        let t0 = Instant::now();
        let handles: Vec<_> = (0..streams)
            .map(|s| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut env =
                        RemoteEnv::connect(&addr, "catch", s as u64, &WrapperCfg::default())
                            .unwrap();
                    let mut obs = vec![0.0; env.spec().obs_len()];
                    env.reset(&mut obs);
                    for i in 0..per_stream {
                        if env.step(i % 3, &mut obs).done {
                            env.reset(&mut obs);
                        }
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let total = (streams * per_stream) as f64;
        println!(
            "{:>10} {:>16.0} {:>18.0}",
            streams,
            total / wall,
            total / wall / streams as f64
        );
    }
    println!(
        "\npaper-shaped check: aggregate steps/s scales with streams (a thread\n\
         per stream — the §5.3 GIL ceiling that motivated PolyBeast's C++\n\
         server does not exist here)."
    );
    Ok(())
}
