//! Bench E8: V-trace three ways — pure Rust, vs the AOT-compiled
//! Pallas-kernel HLO artifact (interpret=True lowering) executed via
//! PJRT — across rollout shapes.  The Rust implementation is the CPU
//! baseline; the artifact number is the *CPU execution* of the
//! TPU-shaped kernel (real-TPU performance is estimated from the VMEM
//! analysis in DESIGN.md §Hardware-Adaptation, not measurable here).

use std::path::Path;

use torchbeast::runtime::tensor::{literal_to_f32s, upload_f32};
use torchbeast::runtime::Module;
use torchbeast::util::rng::Rng;
use torchbeast::util::stats::Bench;
use torchbeast::vtrace;

fn rand_mat(rng: &mut Rng, t: usize, b: usize) -> Vec<Vec<f32>> {
    (0..t)
        .map(|_| (0..b).map(|_| rng.next_f32() * 2.0 - 1.0).collect())
        .collect()
}

fn main() -> anyhow::Result<()> {
    let mut bench = Bench::new("vtrace (E8): rust vs Pallas-HLO artifact");
    let mut rng = Rng::new(0);

    for &(t, b) in &[(20usize, 8usize), (20, 64), (80, 32), (160, 128)] {
        let log_rhos = rand_mat(&mut rng, t, b);
        let discounts = rand_mat(&mut rng, t, b);
        let rewards = rand_mat(&mut rng, t, b);
        let values = rand_mat(&mut rng, t, b);
        let boot: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
        bench.run(&format!("rust T={t} B={b}"), || {
            let out = vtrace::from_importance_weights(
                &log_rhos, &discounts, &rewards, &values, &boot, 1.0, 1.0,
            );
            std::hint::black_box(out);
        });
    }

    // The artifact is compiled for the manifest's (T, B); bench that shape.
    let dir = Path::new("artifacts/catch");
    if dir.join("vtrace.hlo.txt").exists() {
        let manifest = torchbeast::runtime::Manifest::load(dir)?;
        let (t, b) = (manifest.unroll_length, manifest.batch_size);
        let client = xla::PjRtClient::cpu()?;
        let module = Module::load(&client, "vtrace", &dir.join("vtrace.hlo.txt"))?;
        let flat = |m: &Vec<Vec<f32>>| m.iter().flatten().cloned().collect::<Vec<f32>>();
        let log_rhos = rand_mat(&mut rng, t, b);
        let discounts = rand_mat(&mut rng, t, b);
        let rewards = rand_mat(&mut rng, t, b);
        let values = rand_mat(&mut rng, t, b);
        let boot: Vec<f32> = (0..b).map(|_| rng.next_f32()).collect();
        let args = [
            upload_f32(&client, &flat(&log_rhos), &[t, b])?,
            upload_f32(&client, &flat(&discounts), &[t, b])?,
            upload_f32(&client, &flat(&rewards), &[t, b])?,
            upload_f32(&client, &flat(&values), &[t, b])?,
            upload_f32(&client, &boot, &[b])?,
        ];
        let arg_refs: Vec<&xla::PjRtBuffer> = args.iter().collect();
        bench.run(&format!("pallas-hlo artifact T={t} B={b} (incl host<->literal)"), || {
            let out = module.run_buffers(&arg_refs).unwrap();
            std::hint::black_box(out);
        });

        // correctness cross-check while we're here (three-way agreement)
        let out = module.run_buffers(&arg_refs)?;
        let vs_hlo = literal_to_f32s(&out[0])?;
        let rust_out = vtrace::from_importance_weights(
            &log_rhos, &discounts, &rewards, &values, &boot, 1.0, 1.0,
        );
        let mut max_diff = 0.0f32;
        for ti in 0..t {
            for bi in 0..b {
                max_diff = max_diff.max((vs_hlo[ti * b + bi] - rust_out.vs[ti][bi]).abs());
            }
        }
        println!("cross-check rust vs artifact: max |vs diff| = {max_diff:.2e}");
        assert!(max_diff < 1e-4);
    } else {
        println!("(artifacts/catch missing: artifact rows skipped; run `make artifacts`)");
    }

    bench.report();
    Ok(())
}
