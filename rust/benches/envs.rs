//! Bench: raw environment step rates (the substrate E1/E2 stand on).
//! Also measures the wrapper stack's overhead — the Rust analog of the
//! paper's footnote that "even the resizing method can impact
//! performance": preprocessing cost is real and must be known.

use torchbeast::env::wrappers::WrapperCfg;
use torchbeast::env::{make_env, make_wrapped, ENV_NAMES};
use torchbeast::util::rng::Rng;
use torchbeast::util::stats::Bench;

fn main() -> anyhow::Result<()> {
    let mut bench = Bench::new("envs: steps/sec per game (single thread)");
    for name in ENV_NAMES {
        let mut env = make_env(name, 0)?;
        let spec = env.spec().clone();
        let mut obs = vec![0.0f32; spec.obs_len()];
        env.reset(&mut obs);
        let mut rng = Rng::new(1);
        bench.run(name, || {
            for _ in 0..100 {
                let st = env.step(rng.below(spec.num_actions), &mut obs);
                if st.done {
                    env.reset(&mut obs);
                }
            }
        });
    }

    // wrapper stack overhead on breakout
    let cfg = WrapperCfg {
        action_repeat: 4,
        frame_stack: 4,
        reward_clip: 1.0,
        sticky_action_p: 0.25,
        time_limit: 10_000,
        noop_max: 30,
        episodic_life: false,
        env_cost_us: 0,
    };
    let mut env = make_wrapped("minatar/breakout", 0, &cfg)?;
    let spec = env.spec().clone();
    let mut obs = vec![0.0f32; spec.obs_len()];
    env.reset(&mut obs);
    let mut rng = Rng::new(2);
    bench.run("breakout + full wrapper stack (x100)", || {
        for _ in 0..100 {
            let st = env.step(rng.below(spec.num_actions), &mut obs);
            if st.done {
                env.reset(&mut obs);
            }
        }
    });

    bench.report();
    println!("(each iteration = 100 env steps; divide mean by 100 for per-step cost)");
    Ok(())
}
