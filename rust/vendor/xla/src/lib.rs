//! Offline stub of the `xla` (xla-rs) crate.
//!
//! The build environment cannot fetch xla-rs or link a PJRT runtime,
//! so this vendored crate provides the exact API surface
//! `torchbeast::runtime` uses, with two behaviours:
//!
//! * **Host-side types are real.**  [`Literal`] (typed tensors with
//!   dims, reshape, tuple decompose) and [`PjRtBuffer`] (a host copy)
//!   are fully implemented in pure Rust, so every Literal/tensor code
//!   path — checkpoints, manifest loading, conversion helpers — works
//!   and is tested.
//! * **Execution is unavailable.**  [`PjRtClient::compile`] returns an
//!   error: there is no HLO compiler here.  Engine loads fail loudly at
//!   that point; integration tests already skip when the AOT artifact
//!   bundle is absent, so `cargo test` is green without a backend.
//!
//! To run real artifacts, point the `xla` path dependency in
//! `rust/Cargo.toml` at the actual xla-rs checkout — the call sites
//! compile unchanged against either.

use std::fmt;

/// Stub error type (mirrors xla-rs's error enum as an opaque string).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn err<T>(msg: impl Into<String>) -> Result<T> {
    Err(Error(msg.into()))
}

/// Element types the repo moves across the runtime boundary.
pub trait NativeType: Copy + 'static {
    fn to_data(data: &[Self]) -> LiteralData;
    fn from_data(data: &LiteralData) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    fn to_data(data: &[Self]) -> LiteralData {
        LiteralData::F32(data.to_vec())
    }
    fn from_data(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    fn to_data(data: &[Self]) -> LiteralData {
        LiteralData::I32(data.to_vec())
    }
    fn from_data(data: &LiteralData) -> Option<Vec<Self>> {
        match data {
            LiteralData::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// Literal storage: flat typed data, or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum LiteralData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    Tuple(Vec<Literal>),
}

/// A host tensor (xla-rs `Literal` analog).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: LiteralData,
}

impl Literal {
    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal {
            dims: vec![data.len() as i64],
            data: T::to_data(data),
        }
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal {
            dims: Vec::new(),
            data: T::to_data(&[v]),
        }
    }

    /// Tuple literal (what module execution returns for root tuples).
    pub fn tuple(parts: Vec<Literal>) -> Literal {
        Literal {
            dims: Vec::new(),
            data: LiteralData::Tuple(parts),
        }
    }

    pub fn element_count(&self) -> usize {
        match &self.data {
            LiteralData::F32(v) => v.len(),
            LiteralData::I32(v) => v.len(),
            LiteralData::Tuple(_) => 0,
        }
    }

    /// Same data, new dims (element counts must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return err(format!(
                "reshape to {:?} ({} elems) from {} elems",
                dims,
                n,
                self.element_count()
            ));
        }
        Ok(Literal {
            dims: dims.to_vec(),
            data: self.data.clone(),
        })
    }

    /// Copy out as a typed host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::from_data(&self.data).ok_or_else(|| Error("literal element type mismatch".into()))
    }

    /// Split a tuple literal into its parts; returns an empty vec for
    /// non-tuple literals (mirrors xla-rs behaviour relied on by the
    /// runtime's run_buffers).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match &mut self.data {
            LiteralData::Tuple(parts) => Ok(std::mem::take(parts)),
            _ => Ok(Vec::new()),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self.data {
            LiteralData::Tuple(_) => err("tuple literal has no array shape"),
            _ => Ok(ArrayShape {
                dims: self.dims.clone(),
            }),
        }
    }
}

/// Array shape (dims only; the repo is f32/i32-typed via `to_vec`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module text (parsing deferred to a real backend).
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Read an HLO text file.  Validates existence/readability; actual
    /// HLO parsing happens at compile time on a real backend.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path) {
            Ok(text) => Ok(HloModuleProto { text }),
            Err(e) => err(format!("reading HLO text {path}: {e}")),
        }
    }
}

pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _text: proto.text.clone(),
        }
    }
}

/// Device buffer stand-in: a host copy of the uploaded literal.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Compiled executable handle.  Never constructible through the stub
/// (compile errors first), so `execute_b` is unreachable in practice.
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        err("stub xla crate cannot execute HLO")
    }
}

/// PJRT client stand-in.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        err(
            "PJRT backend unavailable: this build vendors a stub `xla` crate \
             (rust/vendor/xla); point the path dependency at a real xla-rs \
             checkout to compile and execute HLO artifacts",
        )
    }

    /// Host → "device" upload (kept as a host literal in the stub).
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        let n: i64 = dims.iter().product();
        if n as usize != data.len() {
            return err(format!(
                "host buffer of {} elems does not match shape {:?}",
                data.len(),
                shape
            ));
        }
        Ok(PjRtBuffer {
            literal: Literal {
                dims,
                data: T::to_data(data),
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let data: Vec<f32> = (0..6).map(|i| i as f32).collect();
        let l = Literal::vec1(&data).reshape(&[2, 3]).unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 3]);
        assert_eq!(l.to_vec::<f32>().unwrap(), data);
        assert!(l.to_vec::<i32>().is_err());
        assert!(l.reshape(&[4, 2]).is_err());
    }

    #[test]
    fn scalar_has_empty_dims() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.array_shape().unwrap().dims(), &[] as &[i64]);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn tuple_decompose() {
        let mut t = Literal::tuple(vec![Literal::scalar(1.0f32), Literal::scalar(2i32)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        // non-tuple literals decompose to empty (runtime relies on this)
        let mut s = Literal::scalar(3i32);
        assert!(s.decompose_tuple().unwrap().is_empty());
    }

    #[test]
    fn upload_validates_shape() {
        let c = PjRtClient::cpu().unwrap();
        assert!(c.buffer_from_host_buffer(&[1.0f32; 6], &[2, 3], None).is_ok());
        assert!(c.buffer_from_host_buffer(&[1.0f32; 5], &[2, 3], None).is_err());
        // scalar: empty shape, one element
        let b = c.buffer_from_host_buffer(&[42i32], &[], None).unwrap();
        assert_eq!(b.to_literal_sync().unwrap().to_vec::<i32>().unwrap(), vec![42]);
    }

    #[test]
    fn compile_fails_loudly() {
        let c = PjRtClient::cpu().unwrap();
        let proto = HloModuleProto {
            text: "HloModule test".into(),
        };
        let comp = XlaComputation::from_proto(&proto);
        let e = c.compile(&comp).unwrap_err();
        assert!(e.to_string().contains("stub"), "{e}");
    }

    #[test]
    fn missing_hlo_file_errors() {
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo.txt").is_err());
    }
}
