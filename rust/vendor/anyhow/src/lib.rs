//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no network access to crates.io, so this
//! vendored crate implements the slice of anyhow's API the repo uses:
//! [`Error`], [`Result`], the [`anyhow!`] / [`bail!`] / [`ensure!`]
//! macros, the [`Context`] extension trait, and source-preserving
//! `downcast_ref`.  Swap the `anyhow` path dependency in
//! `rust/Cargo.toml` for the registry crate if a registry becomes
//! available — call sites need no changes.

use std::error::Error as StdError;
use std::fmt;

/// `anyhow::Result<T>`: a Result defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamic error: a chain of context messages plus an optional
/// underlying source error (preserved for `downcast_ref`).
pub struct Error {
    /// Context messages, outermost first.  For errors created from a
    /// message (`anyhow!`), the last entry is that message.
    chain: Vec<String>,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            chain: vec![message.to_string()],
            source: None,
        }
    }

    /// Wrap a concrete error, preserving it for `downcast_ref`.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error {
            chain: Vec::new(),
            source: Some(Box::new(error)),
        }
    }

    /// Prepend a context message (outermost position).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Downcast to the original concrete error type, if this error was
    /// created from one (possibly wrapped in context since).
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.source.as_deref().and_then(|s| s.downcast_ref::<E>())
    }

    /// The innermost error message (source if present, else the last
    /// context message).
    pub fn root_cause(&self) -> String {
        match &self.source {
            Some(s) => s.to_string(),
            None => self.chain.last().cloned().unwrap_or_default(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        for c in &self.chain {
            if !first {
                write!(f, ": ")?;
            }
            write!(f, "{c}")?;
            first = false;
        }
        if let Some(s) = &self.source {
            if !first {
                write!(f, ": ")?;
            }
            write!(f, "{s}")?;
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self}")
    }
}

// `?` conversion from any std error.  (Error itself does not implement
// std::error::Error, so this blanket impl is coherent — same shape as
// the real anyhow crate.)
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Construct an [`Error`] from a format string or displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)+));
        }
    };
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for std::result::Result<T, Error> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::TimedOut, "took too long")
    }

    #[test]
    fn message_errors_display() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e = anyhow!("bad value {}", 4);
        assert_eq!(e.to_string(), "bad value 4");
    }

    #[test]
    fn bail_and_ensure() {
        fn f(n: usize) -> Result<usize> {
            ensure!(n < 10, "n {} too big", n);
            ensure!(n != 5);
            if n == 3 {
                bail!("three is right out");
            }
            Ok(n)
        }
        assert_eq!(f(2).unwrap(), 2);
        assert!(f(12).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("n != 5"));
        assert!(f(3).unwrap_err().to_string().contains("right out"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::str::from_utf8(&[0xFF])?;
            Ok(s.to_string())
        }
        assert!(f().is_err());
    }

    #[test]
    fn downcast_survives_context() {
        let e: Error = Error::new(io_err()).context("outer");
        let io = e.downcast_ref::<std::io::Error>().unwrap();
        assert_eq!(io.kind(), std::io::ErrorKind::TimedOut);
        assert!(e.to_string().starts_with("outer: "));
        assert!(e.to_string().contains("took too long"));
    }

    #[test]
    fn context_trait_on_results_and_options() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 7)).unwrap_err();
        assert!(e.to_string().contains("step 7"));
        assert!(e.downcast_ref::<std::io::Error>().is_some());

        let o: Option<u32> = None;
        let e = o.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn chained_context_order() {
        let e = Error::msg("root").context("mid").context("outer");
        assert_eq!(e.to_string(), "outer: mid: root");
        assert_eq!(e.root_cause(), "root");
    }
}
